"""Fused FedAvg aggregation engine — the server's per-round hot path.

The seed implementation (`aggregation.fedavg`, kept as the correctness
oracle) reduces N client pytrees with a per-leaf Python loop of N
multiply-adds, each dispatched op-by-op and materializing N fp32
temporaries per leaf.  At cross-silo model sizes this is a pure
memory-bound streaming reduce, so the engine's job is to touch every
client byte exactly once per round.

Dispatch hierarchy (backend-aware, detected once per engine):

  TPU   — flatten-once: each client tree is raveled through a cached
          :class:`RavelPlan` (treedef / shape-layout computed once per
          model structure, reused every round — no per-round retracing
          or re-padding) into one contiguous fp32 ``(N, L)`` buffer,
          reduced by the Pallas ``fedavg_reduce`` kernel (compiled, not
          interpreted), with the stacked buffer *donated* so XLA reuses
          the HBM instead of doubling peak memory.
  CPU/GPU — one jitted fused reduce over the client trees: XLA fuses the
          weighted multiply-add chain per leaf into a single pass over
          the inputs (a dot over the client axis), with no per-round
          Python loop and no ``(N, L)`` materialization.  For buffers
          that are *already* stacked ``(N, L)`` (pod replica stacks,
          benchmarks) the reduce is a single fp32-accumulated
          ``jnp.einsum``.

A chunked mode (`reduce_flat(..., chunk_elems=...)`) streams the reduce
in O(N·block) rather than O(N·L) working memory, and
:class:`StreamingAggregator` folds clients in *as they land* (running
weighted accumulation with an O(L) donated-in-place accumulator), so
asynchronously arriving silos never require holding all N models.

Deadline-driven partial rounds (see :mod:`repro.federated.async_server`)
park updates that miss a round's ``T_round`` in a :class:`CarryOverBuffer`;
the next round's :class:`StreamingAggregator` drains it first, folding each
late silo with a staleness-discounted weight (``StreamingAggregator
.add_stale`` / ``fold_carry``), so no silo's contribution is ever dropped.

Hierarchical aggregation (see :mod:`repro.federated.hierarchy`) composes
aggregators into a tree: a regional aggregator exports its padded fp32
accumulator + weight total as a :class:`PartialSum`
(:meth:`StreamingAggregator.export_partial`) and a parent folds it with
:meth:`StreamingAggregator.fold_partial` — weighted partial sums compose
associatively, so the two-level fold is the same weighted average the
flat engine computes.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import (
    Any,
    Callable,
    ClassVar,
    Dict,
    List,
    Mapping,
    Optional,
    Sequence,
    Tuple,
    Union,
    cast,
)

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Ravel plans: flatten/unflatten compiled once per model structure
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RavelPlan:
    """Cached flatten/unflatten layout for one pytree structure.

    ``flatten_stack`` ravels a *list* of N structurally-identical trees
    into one contiguous fp32 ``(N, L)`` buffer in a single jitted call;
    ``unflatten`` restores an ``(L,)`` vector to the original treedef,
    shapes, and per-leaf dtypes.  Both are traced exactly once per model
    structure (the plan is cached), so the per-round cost is pure data
    movement.  ``signature`` is a stable digest of the structure key
    (treedef + shapes + dtypes) — the cheap equality token
    :class:`PartialSum` carries so a parent aggregator can validate a
    regional partial against its own plan without shipping treedefs.
    """

    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    sizes: Tuple[int, ...]
    total_elems: int
    signature: str
    flatten: Callable[[Any], Any]
    flatten_stack: Callable[[Sequence[Any]], Any]
    unflatten: Callable[[Any], Any]


# Bounded LRU: hierarchical / multi-model serving churns tree structures,
# so an unbounded module-global would grow forever (each plan pins two
# jitted closures) and leak across engines.  Hits move the plan to the
# back; inserts evict from the front.  Plans held by live aggregators
# survive eviction — only the cache entry (and its reuse) is dropped.
# Holds both full RavelPlans (keyed by structure) and GroupPlans (keyed
# by (structure, ("group", leaf indices))) — the composite key is what
# keeps two schemas' masked subtrees of the same tree from colliding.
_PLAN_CACHE: "OrderedDict[Any, Any]" = OrderedDict()
_PLAN_CACHE_MAX: int = 64


def _structure_key(tree: Any) -> Any:
    leaves, treedef = jax.tree.flatten(tree)
    return (
        treedef,
        tuple(tuple(l.shape) for l in leaves),
        tuple(jnp.result_type(l).name for l in leaves),
    )


def clear_plan_cache() -> None:
    """Drop every cached :class:`RavelPlan` (tests / structure churn)."""
    _PLAN_CACHE.clear()


def plan_cache_size() -> int:
    """Number of plans currently cached (bounded by the LRU limit)."""
    return len(_PLAN_CACHE)


def set_plan_cache_limit(max_plans: int) -> int:
    """Set the LRU bound on the module-global plan cache; returns it.

    Shrinking below the current population evicts oldest-first
    immediately.  The default (64) covers dozens of concurrently-served
    model structures; raise it for multi-model zoos, lower it in
    memory-tight tests."""
    global _PLAN_CACHE_MAX
    if max_plans < 1:
        raise ValueError("plan cache limit must be >= 1")
    _PLAN_CACHE_MAX = int(max_plans)
    while len(_PLAN_CACHE) > _PLAN_CACHE_MAX:
        _PLAN_CACHE.popitem(last=False)
    return _PLAN_CACHE_MAX


def plan_for(tree: Any) -> RavelPlan:
    """Return the (LRU-cached) RavelPlan for ``tree``'s structure."""
    key = _structure_key(tree)
    plan = _PLAN_CACHE.get(key)
    if plan is not None:
        _PLAN_CACHE.move_to_end(key)
        return cast(RavelPlan, plan)

    leaves, treedef = jax.tree.flatten(tree)
    if not leaves:
        raise ValueError("cannot build a ravel plan for an empty pytree")
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(jnp.result_type(l) for l in leaves)
    sizes = tuple(int(np.prod(s)) if s else 1 for s in shapes)
    total = int(sum(sizes))
    signature = hashlib.sha1(repr(key).encode()).hexdigest()[:16]

    def flatten(t: Any) -> Any:
        ls = jax.tree.leaves(t)
        return jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in ls])

    def flatten_stack(trees: Sequence[Any]) -> Any:
        rows = [
            jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in jax.tree.leaves(t)])
            for t in trees
        ]
        return jnp.stack(rows)

    def unflatten(vec: Any) -> Any:
        outs = []
        off = 0
        for shape, dtype, size in zip(shapes, dtypes, sizes):
            outs.append(vec[off:off + size].reshape(shape).astype(dtype))
            off += size
        return jax.tree.unflatten(treedef, outs)

    plan = RavelPlan(
        treedef=treedef, shapes=shapes, dtypes=dtypes, sizes=sizes,
        total_elems=total, signature=signature,
        flatten=jax.jit(flatten), flatten_stack=jax.jit(flatten_stack),
        unflatten=jax.jit(unflatten),
    )
    _PLAN_CACHE[key] = plan
    while len(_PLAN_CACHE) > _PLAN_CACHE_MAX:
        _PLAN_CACHE.popitem(last=False)
    return plan


# ---------------------------------------------------------------------------
# Structure validation (typed errors instead of opaque tree.map failures)
# ---------------------------------------------------------------------------

class StructureMismatchError(ValueError):
    """A client's update pytree diverges from the fold's structure.

    Raised (instead of an opaque ``jax.tree.map`` error — or worse, a
    silent broadcast) the moment a second client's treedef or leaf
    shapes fail to match the structure the fold was pinned to.  Carries
    the offending ``client_id`` (when the caller supplied one) and the
    first mismatching leaf ``path``."""

    def __init__(
        self,
        message: str,
        client_id: Optional[str] = None,
        path: Optional[str] = None,
    ) -> None:
        super().__init__(message)
        self.client_id = client_id
        self.path = path


def _leaf_paths(treedef: Any) -> List[str]:
    """Human-readable key paths for every leaf slot of a treedef."""
    dummy = jax.tree.unflatten(treedef, list(range(treedef.num_leaves)))
    kps, _ = jax.tree_util.tree_flatten_with_path(dummy)
    return [jax.tree_util.keystr(kp) or "<root>" for kp, _ in kps]


def _first_structure_mismatch(
    ref_treedef: Any,
    ref_shapes: Tuple[Tuple[int, ...], ...],
    params: Any,
) -> Optional[Tuple[str, str]]:
    """``(leaf path, detail)`` of the first divergence, or None if the
    update matches the reference treedef + leaf shapes (dtypes are NOT
    compared: mixed-precision clients fold through the fp32 cast)."""
    leaves, treedef = jax.tree.flatten(params)
    shapes = tuple(tuple(np.shape(l)) for l in leaves)
    if treedef == ref_treedef:
        if shapes == ref_shapes:
            return None
        for path, got, want in zip(_leaf_paths(treedef), shapes, ref_shapes):
            if got != want:
                return path, f"leaf shape {got} != expected {want}"
        return "<root>", "leaf shapes diverge"
    ref_paths = _leaf_paths(ref_treedef)
    got_paths = _leaf_paths(treedef)
    for rp, gp in zip(ref_paths, got_paths):
        if rp != gp:
            return gp, f"unexpected leaf (expected {rp} here)"
    if len(got_paths) != len(ref_paths):
        longer = got_paths if len(got_paths) > len(ref_paths) else ref_paths
        extra = longer[min(len(got_paths), len(ref_paths))]
        kind = "extra" if len(got_paths) > len(ref_paths) else "missing"
        return extra, (
            f"{kind} leaf: update has {len(got_paths)} leaves, "
            f"expected {len(ref_paths)}"
        )
    return "<root>", f"treedef {treedef} != expected {ref_treedef}"


def _raise_structure_mismatch(
    mismatch: Tuple[str, str], client_id: Optional[str]
) -> None:
    path, detail = mismatch
    who = f"client {client_id!r}" if client_id is not None else "an update"
    raise StructureMismatchError(
        f"update from {who} does not match the fold's pytree structure "
        f"at leaf {path!r}: {detail}",
        client_id=client_id,
        path=path,
    )


# ---------------------------------------------------------------------------
# Update schemas: named parameter groups over one model structure
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GroupPlan:
    """Cached flatten layout for one named subset of a tree's leaves.

    The structured analogue of :class:`RavelPlan`: ``flatten`` ravels
    the *selected* leaves of a full tree (in full-plan leaf order) into
    one compact fp32 ``(total_elems,)`` vector, and ``offsets`` maps
    each compact position back into the full flat vector so a finalize
    can scatter per-group accumulators into one model-sized numerator.
    ``padded_len`` rounds the compact length up to the Pallas BLOCK
    multiple (== the compression QBLOCK), so per-group int8/fp16 deltas
    feed the fused dequantize-and-fold kernel exactly like whole-model
    ones.  ``signature`` digests (full-plan signature, leaf indices) —
    the equality token per-group partial sums carry."""

    leaf_indices: Tuple[int, ...]
    sizes: Tuple[int, ...]
    total_elems: int
    padded_len: int
    signature: str
    offsets: Any  # np.int32 positions in the full flat vector
    flatten: Callable[[Any], Any]


def group_plan_for(tree: Any, leaf_indices: Sequence[int]) -> GroupPlan:
    """The (LRU-cached) :class:`GroupPlan` for a subset of ``tree``'s leaves.

    Cached in the same bounded LRU as full ravel plans, but keyed by
    ``(structure, ("group", indices))`` — two schemas selecting different
    subtrees of one structure get *distinct* plans (and distinct
    signatures), never a colliding cache slot."""
    full = plan_for(tree)
    idx = tuple(sorted(int(i) for i in leaf_indices))
    if not idx:
        raise ValueError("a parameter group must select at least one leaf")
    if len(set(idx)) != len(idx):
        raise ValueError(f"duplicate leaf indices in group selection: {idx}")
    if idx[0] < 0 or idx[-1] >= len(full.sizes):
        raise ValueError(
            f"group leaf indices {idx} out of range for a "
            f"{len(full.sizes)}-leaf structure"
        )
    key = (_structure_key(tree), ("group", idx))
    cached = _PLAN_CACHE.get(key)
    if cached is not None:
        _PLAN_CACHE.move_to_end(key)
        return cast(GroupPlan, cached)

    from repro.kernels.fedavg_reduce import BLOCK as _block

    sizes = tuple(int(full.sizes[i]) for i in idx)
    total = int(sum(sizes))
    padded = -(-total // _block) * _block
    signature = hashlib.sha1(
        f"{full.signature}:group:{idx!r}".encode()
    ).hexdigest()[:16]
    starts = np.concatenate(
        [np.zeros(1, np.int64), np.cumsum(np.asarray(full.sizes, np.int64))]
    )
    offsets = np.concatenate(
        [np.arange(starts[i], starts[i] + full.sizes[i], dtype=np.int64)
         for i in idx]
    ).astype(np.int32)

    def flatten(t: Any) -> Any:
        ls = jax.tree.leaves(t)
        return jnp.concatenate(
            [jnp.ravel(ls[i]).astype(jnp.float32) for i in idx]
        )

    plan = GroupPlan(
        leaf_indices=idx, sizes=sizes, total_elems=total, padded_len=padded,
        signature=signature, offsets=offsets, flatten=jax.jit(flatten),
    )
    _PLAN_CACHE[key] = plan
    while len(_PLAN_CACHE) > _PLAN_CACHE_MAX:
        _PLAN_CACHE.popitem(last=False)
    return plan


def _select_leaves(name: str, selector: Any, tree: Any, paths: Sequence[str]) -> Tuple[int, ...]:
    """Leaf indices a group selector picks out of ``tree``.

    Selector forms: a substring matched against the leaf's key path
    (``"lora_"``), a sequence of substrings (any match), a
    ``path -> bool`` callable, or a boolean mask pytree with the same
    leaf count as the model (truthy leaf = selected)."""
    if isinstance(selector, str):
        return tuple(i for i, p in enumerate(paths) if selector in p)
    if isinstance(selector, (list, tuple)) and all(
        isinstance(s, str) for s in selector
    ):
        toks = list(selector)
        return tuple(
            i for i, p in enumerate(paths) if any(t in p for t in toks)
        )
    if callable(selector):
        return tuple(i for i, p in enumerate(paths) if bool(selector(p)))
    mask_leaves = jax.tree.leaves(selector)
    if len(mask_leaves) != len(paths):
        raise ValueError(
            f"schema group {name!r}: boolean mask has {len(mask_leaves)} "
            f"leaves, the model has {len(paths)}"
        )
    return tuple(i for i, m in enumerate(mask_leaves) if bool(np.all(m)))


class UpdateSchema:
    """Named parameter groups over one model structure (order preserved).

    The first-class description of a *structured* update: each group
    names a subset of the model's leaves (see :func:`_select_leaves` for
    selector forms), and clients may ship any subset of the groups —
    silos absent from a group contribute no weight to it.  Groups may
    overlap; an element covered by several groups normalizes by the sum
    of the covering groups' weight totals.  ``resolve(tree)`` binds the
    schema to a concrete structure, building (cached) per-group plans.
    """

    def __init__(
        self,
        groups: Union[Mapping[str, Any], Sequence[Tuple[str, Any]]],
    ) -> None:
        items: List[Tuple[str, Any]]
        if isinstance(groups, Mapping):
            items = [(str(n), s) for n, s in groups.items()]
        else:
            items = [(str(n), s) for n, s in groups]
        if not items:
            raise ValueError("an UpdateSchema needs at least one group")
        names = [n for n, _ in items]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate group names in schema: {names}")
        for n, sel in items:
            if sel is None:
                raise ValueError(
                    f"schema group {n!r} has no selector (None)"
                )
        self.groups: Tuple[Tuple[str, Any], ...] = tuple(items)

    @property
    def group_names(self) -> Tuple[str, ...]:
        return tuple(n for n, _ in self.groups)

    def __repr__(self) -> str:
        return f"UpdateSchema({', '.join(self.group_names)})"

    def resolve(self, tree: Any) -> "ResolvedSchema":
        """Bind the schema to ``tree``'s structure (per-group plans)."""
        full = plan_for(tree)
        paths = _leaf_paths(full.treedef)
        resolved: List[Tuple[str, GroupPlan]] = []
        for name, sel in self.groups:
            idx = _select_leaves(name, sel, tree, paths)
            if not idx:
                raise ValueError(
                    f"schema group {name!r} selects no leaves of the model "
                    f"(selector {sel!r}; leaf paths: {paths[:8]}...)"
                )
            resolved.append((name, group_plan_for(tree, idx)))
        leaf_groups = tuple(
            tuple(n for n, gp in resolved if i in set(gp.leaf_indices))
            for i in range(len(full.sizes))
        )
        signature = hashlib.sha1(
            (full.signature + "".join(
                f"|{n}:{gp.signature}" for n, gp in resolved
            )).encode()
        ).hexdigest()[:16]
        return ResolvedSchema(
            plan=full, groups=tuple(resolved), signature=signature,
            leaf_groups=leaf_groups,
        )


def as_update_schema(
    spec: Union[None, "UpdateSchema", Mapping[str, Any]],
) -> Optional["UpdateSchema"]:
    """Coerce a user-facing schema knob into an :class:`UpdateSchema`.

    Accepts ``None`` (off), an existing schema, or a mapping of group
    name -> selector.  Raises ``ValueError`` on anything else — the
    builder calls this at configuration time so bad knobs fail before
    any round runs."""
    if spec is None:
        return None
    if isinstance(spec, UpdateSchema):
        return spec
    if isinstance(spec, Mapping):
        return UpdateSchema(spec)
    raise ValueError(
        f"schema must be None, an UpdateSchema, or a mapping of group "
        f"name -> selector; got {type(spec).__name__}"
    )


@dataclasses.dataclass(frozen=True)
class ResolvedSchema:
    """An :class:`UpdateSchema` bound to one concrete model structure.

    ``leaf_groups[i]`` names the groups covering leaf ``i`` (in schema
    order) — the coverage map the structured finalize normalizes with.
    ``signature`` digests the full plan plus every group's plan, so two
    endpoints agreeing on a signature agree on the exact partition."""

    plan: RavelPlan
    groups: Tuple[Tuple[str, GroupPlan], ...]
    signature: str
    leaf_groups: Tuple[Tuple[str, ...], ...]

    @property
    def group_names(self) -> Tuple[str, ...]:
        return tuple(n for n, _ in self.groups)

    def group(self, name: str) -> GroupPlan:
        for n, gp in self.groups:
            if n == name:
                return gp
        raise KeyError(f"schema has no group {name!r}")

    @property
    def full_coverage(self) -> bool:
        """Every leaf in exactly one group (the dense-equivalent case)."""
        return all(len(gs) == 1 for gs in self.leaf_groups)

    @property
    def covered(self) -> bool:
        """Every leaf in at least one group."""
        return all(len(gs) >= 1 for gs in self.leaf_groups)

    @property
    def disjoint(self) -> bool:
        """No leaf in more than one group."""
        return all(len(gs) <= 1 for gs in self.leaf_groups)


# ---------------------------------------------------------------------------
# Fused flat reduces
# ---------------------------------------------------------------------------

def _dot_reduce(stacked: Any, w: Any) -> Any:
    """(N, L) x (N,) -> (L,): single fp32-accumulated contraction.

    ``w`` must already be normalized."""
    out = jnp.einsum("n,nl->l", w, stacked, preferred_element_type=jnp.float32)
    return out.astype(stacked.dtype)


def _pallas_flat_reduce(stacked: Any, weights: Any, interpret: Any) -> Any:
    from repro.kernels.fedavg_reduce import fedavg_reduce as _kernel
    return _kernel(stacked, weights, interpret=interpret)


def fused_stacked_tree_reduce(stacked: Any, weights: Any) -> Any:
    """Traceable FedAvg over a pytree with a leading client/pod axis.

    Flattens every leaf of the replica stack into one ``(N, L)`` buffer
    and reduces it with a single fused contraction (Pallas kernel on
    TPU, fp32 einsum elsewhere) instead of a per-leaf ``tree.map`` —
    this is the fused call `pod_fedavg` lowers inside `fl_round_step`.
    """
    leaves, treedef = jax.tree.flatten(stacked)
    if not leaves:
        return stacked
    n = leaves[0].shape[0]
    w = weights.astype(jnp.float32)
    flat = jnp.concatenate(
        [l.reshape(n, -1).astype(jnp.float32) for l in leaves], axis=1
    )
    if jax.default_backend() == "tpu":
        red = _pallas_flat_reduce(flat, w, interpret=False)
    else:
        red = _dot_reduce(flat, w / jnp.sum(w))
    outs = []
    off = 0
    for l in leaves:
        size = int(np.prod(l.shape[1:])) if l.ndim > 1 else 1
        outs.append(red[off:off + size].reshape(l.shape[1:]).astype(l.dtype))
        off += size
    return jax.tree.unflatten(treedef, outs)


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AggStats:
    """Engine counters: `n_traces` counts XLA retraces (a steady-state
    round must hit the jit cache, i.e. n_traces stays flat while n_calls
    grows).  Byte volume is tracked on two axes that diverge once updates
    arrive compressed: ``wire_bytes`` is what actually crossed the
    transport (the compressed frame), ``folded_bytes`` the dense fp32
    equivalent the reduce is worth (for GB/s accounting).  For dense
    updates the two are equal."""

    n_calls: int = 0
    n_traces: int = 0
    last_wire_bytes: int = 0
    total_wire_bytes: int = 0
    last_folded_bytes: int = 0
    total_folded_bytes: int = 0

    def record(self, folded: int, wire: Optional[int] = None) -> None:
        """Account one update: dense-equivalent bytes, and wire bytes if
        they differ (``wire=None`` means the update arrived dense)."""
        w = folded if wire is None else wire
        self.last_wire_bytes = w
        self.total_wire_bytes += w
        self.last_folded_bytes = folded
        self.total_folded_bytes += folded

    # Back-compat aliases: `last_bytes`/`total_bytes` always meant the
    # dense in-memory volume of the reduce, which is the folded axis.
    @property
    def last_bytes(self) -> int:
        return self.last_folded_bytes

    @property
    def total_bytes(self) -> int:
        return self.total_folded_bytes


class AggregationEngine:
    """Backend-aware fused FedAvg reducer with cached per-model plans.

    Parameters
    ----------
    backend : override ``jax.default_backend()`` ("tpu" enables the
        flatten-once + Pallas + donation path).
    use_pallas : force the kernel path on/off (defaults to backend=="tpu").
    interpret : explicit Pallas interpret-mode override (tests); None
        defers to backend detection in `kernels.ops`.
    chunk_elems : if set, `reduce_flat` streams in column blocks of this
        many elements (O(N·block) working memory).
    """

    def __init__(
        self,
        backend: Optional[str] = None,
        use_pallas: Optional[bool] = None,
        interpret: Optional[bool] = None,
        chunk_elems: Optional[int] = None,
    ) -> None:
        self.backend = backend if backend is not None else jax.default_backend()
        self.use_pallas = (self.backend == "tpu") if use_pallas is None else use_pallas
        self.interpret = interpret
        self.chunk_elems = chunk_elems
        self.stats = AggStats()
        self._tree_reduce_cache: Dict[Any, Callable[..., Any]] = {}

    # -- weights -------------------------------------------------------------
    @staticmethod
    def _normalized_weights(weights: Sequence[float]) -> Any:
        w = np.asarray(weights, np.float64)
        if w.ndim != 1 or w.size == 0:
            raise ValueError("weights must be a non-empty 1-D sequence")
        if w.sum() <= 0:
            raise ValueError("aggregation weights must sum to a positive value")
        return (w / w.sum()).astype(np.float32)

    # -- tree path (FLServer hot path) ---------------------------------------
    def aggregate(self, client_params: Sequence[Any], weights: Sequence[float]) -> Any:
        """Weighted average of N client pytrees in one fused call.

        Numerically equivalent to the `aggregation.fedavg` oracle (fp32
        accumulation, cast back to each leaf's dtype) but with exactly
        one pass over the client bytes per round.
        """
        w = self._normalized_weights(weights)
        if len(client_params) != w.size:
            raise ValueError("len(client_params) != len(weights)")
        self.stats.n_calls += 1
        nbytes = sum(l.nbytes for t in client_params for l in jax.tree.leaves(t))
        self.stats.record(nbytes)

        if self.use_pallas:
            plan = plan_for(client_params[0])
            stacked = plan.flatten_stack(list(client_params))
            red = self.reduce_flat(stacked, jnp.asarray(w))
            return plan.unflatten(red)

        fn = self._get_tree_reduce(client_params)
        return fn(list(client_params), jnp.asarray(w))

    def _get_tree_reduce(self, client_params: Sequence[Any]) -> Callable[..., Any]:
        key = (len(client_params), _structure_key(client_params[0]))
        fn = self._tree_reduce_cache.get(key)
        if fn is not None:
            return fn
        stats = self.stats

        def tree_reduce(trees: Any, w: Any) -> Any:
            stats.n_traces += 1  # executes at trace time only

            def avg(*leaves: Any) -> Any:
                acc = leaves[0].astype(jnp.float32) * w[0]
                for i in range(1, len(leaves)):
                    acc = acc + leaves[i].astype(jnp.float32) * w[i]
                return acc.astype(leaves[0].dtype)

            return jax.tree.map(avg, *trees)

        fn = jax.jit(tree_reduce)
        self._tree_reduce_cache[key] = fn
        return fn

    # -- flat path ((N, L) stacked buffers) ----------------------------------
    def reduce_flat(
        self,
        stacked: Any,
        weights: Any,
        donate: Optional[bool] = None,
        chunk_elems: Optional[int] = None,
    ) -> Any:
        """Weighted average over axis 0 of a contiguous (N, L) buffer.

        ``donate=True`` hands the stacked buffer to XLA (the caller must
        not reuse it); defaults to donating only on the Pallas/TPU path,
        where the buffer would otherwise be duplicated for padding.
        Chunked mode slices the buffer, so donation does not apply there
        (an explicit ``donate=True`` with chunking is an error).
        """
        if stacked.ndim != 2:
            raise ValueError(f"expected (N, L) stacked buffer, got {stacked.shape}")
        w = weights.astype(jnp.float32)
        chunk = chunk_elems if chunk_elems is not None else self.chunk_elems
        if chunk:
            if donate:
                raise ValueError("chunked reduce slices the buffer; donation "
                                 "does not apply (pass donate=False/None)")
            return self._reduce_flat_chunked(stacked, w, int(chunk))
        if donate is None:
            donate = self.use_pallas and self.backend == "tpu"
        return self._get_flat_reduce(donate)(stacked, w)

    def _get_flat_reduce(self, donate: bool) -> Callable[..., Any]:
        """Per-engine jitted flat reduce (trace-counted, backend-routed)."""
        key = ("flat", self.use_pallas, bool(donate))
        fn = self._tree_reduce_cache.get(key)
        if fn is not None:
            return fn
        stats = self.stats
        if self.use_pallas:
            interp = self.interpret
            if interp is None:
                from repro.kernels.ops import _interpret_default
                interp = _interpret_default()

            def flat_reduce(stacked: Any, w: Any) -> Any:
                stats.n_traces += 1  # executes at trace time only
                return _pallas_flat_reduce(stacked, w, interpret=interp)
        else:
            def flat_reduce(stacked: Any, w: Any) -> Any:
                stats.n_traces += 1  # executes at trace time only
                return _dot_reduce(stacked, w / jnp.sum(w))

        fn = jax.jit(flat_reduce, donate_argnums=(0,) if donate else ())
        self._tree_reduce_cache[key] = fn
        return fn

    def _reduce_flat_chunked(self, stacked: Any, w: Any, chunk: int) -> Any:
        """Column-blocked streaming reduce: O(N*chunk) working set.

        Each block goes through the same backend-routed reduce as the
        unchunked path (Pallas kernel when use_pallas, einsum otherwise)."""
        _, L = stacked.shape
        fn = self._get_flat_reduce(donate=False)
        outs = [fn(stacked[:, off:off + chunk], w) for off in range(0, L, chunk)]
        return jnp.concatenate(outs) if len(outs) > 1 else outs[0]

    # -- streaming -----------------------------------------------------------
    def streaming(
        self,
        base: Any = None,
        base_round: Optional[int] = None,
        schema: Union[None, "UpdateSchema", "ResolvedSchema", Mapping[str, Any]] = None,
    ) -> Union["StreamingAggregator", "StructuredStreamingAggregator"]:
        """New per-round streaming accumulator (async client folding).

        ``base`` switches the aggregator to flat/delta mode anchored on
        the round's global weights — required to fold
        :class:`~repro.federated.compression.CompressedUpdate` payloads
        (deltas against ``base``) and numerically identical to the plain
        weighted average for dense updates (the base cancels exactly).
        ``base_round`` tags the base so compressed updates carrying a
        ``base_round`` of their own are validated against it (a delta
        folded against the wrong round's base is silent corruption —
        see :meth:`StreamingAggregator.rebase`).

        ``schema`` switches to *structured* mode: per-group accumulators
        under an :class:`UpdateSchema` (named parameter groups), folding
        partial updates with per-group weight normalization — see
        :class:`StructuredStreamingAggregator`.  Structured mode needs
        ``base`` (absent groups keep the base's values)."""
        if schema is not None:
            if base is None:
                raise ValueError(
                    "streaming(schema=...) needs base=global_params: absent "
                    "groups and per-group deltas are defined relative to it"
                )
            return StructuredStreamingAggregator(
                self, schema, base, base_round=base_round
            )
        return StreamingAggregator(self, base=base, base_round=base_round)


# ---------------------------------------------------------------------------
# Streaming / incremental accumulation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CarryEntry:
    """One late ``c_msg_train`` buffered for a later round's average.

    The update was computed against ``origin_round``'s global weights; when
    it is finally folded, its example weight is discounted by the staleness
    factor ``discount ** (fold_round - origin_round)`` so fresh silos
    dominate while the straggler's contribution still lands (never silently
    dropped).

    ``params`` must be a *dense* pytree: a compressed update encodes a
    delta against its origin round's base, which a later round no longer
    has — the async engine dequantizes at park time
    (:func:`repro.federated.compression.materialize_update`) so the
    parked value is base-independent."""

    client_id: str
    params: Any
    weight: float       # raw example weight (n_samples), undiscounted
    origin_round: int   # round whose deadline the message missed
    late_by_s: float = 0.0  # virtual seconds past that round's deadline
    # ||update - origin base||_2 at park time, when the engine had a base
    # to measure against; lets DriftAwareDiscount compare how far the
    # global model has since moved relative to the parked update's own
    # step size.  None = not measured (dense park without a base).
    origin_delta_norm: Optional[float] = None

    def age_at(self, round_idx: int) -> int:
        """Rounds of staleness when folded in ``round_idx`` (floor 1).

        The single source of the age rule — `fold_carry` and the async
        round engine's timed drain both discount by ``discount**age_at``."""
        return max(1, round_idx - self.origin_round)


class CarryOverBuffer:
    """Late updates parked between rounds (deadline-driven partial rounds).

    The async round engine defers any ``c_msg_train`` that misses its
    round's ``T_round`` deadline into this buffer; the next round's
    :class:`StreamingAggregator` drains it first (the messages are already
    on the server), folding each entry with a staleness-discounted weight.
    """

    def __init__(self) -> None:
        self._entries: List[CarryEntry] = []

    def defer(self, entry: CarryEntry) -> None:
        self._entries.append(entry)

    def drain(self) -> List[CarryEntry]:
        entries, self._entries = self._entries, []
        return entries

    def clients(self) -> List[str]:
        return [e.client_id for e in self._entries]

    def snapshot(self) -> List[CarryEntry]:
        """Non-destructive view of the parked entries (oldest first)."""
        return list(self._entries)

    def pending_weight(self) -> float:
        """Total raw (undiscounted) example weight awaiting a fold."""
        return sum(e.weight for e in self._entries)

    def __len__(self) -> int:
        return len(self._entries)

    def __bool__(self) -> bool:
        return bool(self._entries)


# ---------------------------------------------------------------------------
# Staleness policies: how much weight a carried-over update keeps
# ---------------------------------------------------------------------------

class StalenessPolicy:
    """How much of a parked update's weight survives a late fold.

    ``effective_multiplier`` maps one :class:`CarryEntry` to the factor
    its raw example weight is scaled by when finally folded in
    ``round_idx``.  Policies advertising ``uses_drift`` additionally
    receive ``drift`` — the ratio of how far the global model has moved
    since the update was parked to the update's own step size — so the
    discount can track *observed* divergence rather than just age."""

    uses_drift: ClassVar[bool] = False

    def effective_multiplier(
        self,
        entry: CarryEntry,
        round_idx: int,
        drift: Optional[float] = None,
    ) -> float:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class AgeDiscount(StalenessPolicy):
    """The PR-3 rule: ``discount ** age`` with age floored at 1 round.

    Bit-identical to :meth:`StreamingAggregator.add_stale`'s arithmetic
    (same ``float(discount) ** int(age)`` expression), so swapping the
    default policy in changes nothing for existing runs."""

    discount: float = 0.5

    def effective_multiplier(
        self,
        entry: CarryEntry,
        round_idx: int,
        drift: Optional[float] = None,
    ) -> float:
        return float(self.discount) ** int(entry.age_at(round_idx))


@dataclasses.dataclass(frozen=True)
class DriftAwareDiscount(StalenessPolicy):
    """Convergence-aware staleness: decay by observed update drift.

    Starts from the same age discount, then divides by
    ``1 + drift_coef * (drift - 1)`` when the model has drifted *more*
    than the parked update's own step (``drift > 1``) — a late update
    pointing at a distant past model is down-weighted harder than its
    age alone implies.  When drift is small (the model barely moved, so
    the stale direction is still informative) or unmeasurable (no base
    at park time), the policy reduces exactly to :class:`AgeDiscount`.
    """

    discount: float = 0.5
    drift_coef: float = 1.0

    uses_drift: ClassVar[bool] = True

    def effective_multiplier(
        self,
        entry: CarryEntry,
        round_idx: int,
        drift: Optional[float] = None,
    ) -> float:
        base = float(self.discount) ** int(entry.age_at(round_idx))
        if drift is None or drift <= 1.0:
            return base
        return base / (1.0 + float(self.drift_coef) * (float(drift) - 1.0))


def _scale_tree_impl(tree: Any, w: Any) -> Any:
    return jax.tree.map(lambda l: l.astype(jnp.float32) * w, tree)


_scale_tree: Callable[..., Any] = jax.jit(_scale_tree_impl)


# The accumulator is donated: same shape/dtype in and out, so XLA updates
# it in place — O(L) extra memory total, regardless of client count.
def _accum_tree_impl(acc: Any, tree: Any, w: Any) -> Any:
    return jax.tree.map(lambda a, l: a + l.astype(jnp.float32) * w, acc, tree)


_accum_tree: Callable[..., Any] = jax.jit(_accum_tree_impl, donate_argnums=(0,))


def _scale_acc_impl(acc: Any, inv: Any) -> Any:
    return jax.tree.map(lambda a: a * inv, acc)


_scale_acc: Callable[..., Any] = jax.jit(_scale_acc_impl, donate_argnums=(0,))


# Flat-mode (delta) folds: the padded fp32 accumulator is donated so XLA
# updates it in place, exactly like the tree-mode `_accum_tree`.
def _flat_delta_fold_impl(acc: Any, flat: Any, base: Any, w: Any) -> Any:
    """acc[:L] += (flat - base) * w — dense update folded as a delta."""
    return acc.at[: base.shape[0]].add((flat - base) * w)


_flat_delta_fold: Callable[..., Any] = jax.jit(
    _flat_delta_fold_impl, donate_argnums=(0,)
)


def _flat_scatter_fold_impl(acc: Any, idx: Any, vals: Any, w: Any) -> Any:
    """acc[idx] += vals * w — the top-k sparse fold (fp16 values)."""
    return acc.at[idx].add(vals.astype(jnp.float32) * w)


_flat_scatter_fold: Callable[..., Any] = jax.jit(
    _flat_scatter_fold_impl, donate_argnums=(0,)
)


def _flat_dequant_fold_jnp_impl(acc: Any, data: Any, scales: Any, w: Any) -> Any:
    """Fused dequantize-and-fold for einsum-tier backends: one jitted
    pass, same per-block math as the Pallas `dequant_fold` kernel."""
    nb = scales.shape[0]
    x = data.reshape(nb, -1).astype(jnp.float32)
    return acc + ((w * scales)[:, None] * x).reshape(acc.shape)


_flat_dequant_fold_jnp: Callable[..., Any] = jax.jit(
    _flat_dequant_fold_jnp_impl, donate_argnums=(0,)
)


# A regional partial sum is another padded fp32 accumulator: folding it
# is a donated elementwise add (partial sums compose associatively).
def _flat_partial_fold_impl(acc: Any, other: Any) -> Any:
    """acc += other — fold a regional partial accumulator in."""
    return acc + other


_flat_partial_fold: Callable[..., Any] = jax.jit(
    _flat_partial_fold_impl, donate_argnums=(0,)
)


def _flat_finalize_impl(acc: Any, base: Any, inv: Any) -> Any:
    """base + acc[:L] * inv — the flat-mode weighted average.  The padded
    accumulator is NOT donated here: the (L,) output can't alias it."""
    return base + acc[: base.shape[0]] * inv


_flat_finalize: Callable[..., Any] = jax.jit(_flat_finalize_impl)


# Structured-finalize helpers: per-group compact accumulators scatter
# into one model-sized numerator (exact: every target starts at 0, so
# the scatter-add is 0 + x), then normalize elementwise by each leaf's
# covering-group weight total.
def _flat_group_scatter_impl(num: Any, idx: Any, vals: Any) -> Any:
    """num[idx] += vals — place a group's compact accumulator."""
    return num.at[idx].add(vals)


_flat_group_scatter: Callable[..., Any] = jax.jit(
    _flat_group_scatter_impl, donate_argnums=(0,)
)


def _flat_finalize_vec_impl(num: Any, base: Any, inv: Any) -> Any:
    """base + num * inv — elementwise normalizer (uncovered / weightless
    elements carry inv == 0 and keep the base exactly).  Not donated:
    the output aliases neither input."""
    return base + num * inv


_flat_finalize_vec: Callable[..., Any] = jax.jit(_flat_finalize_vec_impl)


def _fold_compressed_into(
    acc: Any,
    update: Any,
    w: float,
    padded_len: int,
    use_pallas: bool,
    interpret: Optional[bool],
) -> Any:
    """Fold one CompressedUpdate's delta into a padded fp32 accumulator.

    The single codec-dispatch used by both the whole-model
    :meth:`StreamingAggregator.add_compressed` and the per-group
    structured fold — identical ops on identical layouts, which is what
    makes a full-coverage structured fold bit-for-bit equal to the dense
    one.  ``acc`` is donated by the underlying jitted folds; callers
    must rebind to the return value."""
    if update.codec == "topk":
        return _flat_scatter_fold(
            acc,
            jnp.asarray(np.asarray(update.indices)),
            jnp.asarray(np.asarray(update.data)),
            jnp.float32(w),
        )
    if update.codec in ("int8", "fp16"):
        from repro.federated.compression import QBLOCK
        nb = padded_len // QBLOCK
        data = np.zeros(padded_len, dtype=update.data.dtype)
        data[: update.total_elems] = update.data
        if update.codec == "int8":
            scales = np.asarray(update.scales, np.float32)
            if scales.shape != (nb,):
                raise ValueError(
                    f"int8 update has {scales.shape} scales; expected ({nb},)"
                )
        else:
            scales = np.ones(nb, np.float32)
        if use_pallas:
            from repro.kernels.fedavg_reduce import dequant_fold
            return dequant_fold(
                acc, jnp.asarray(data), jnp.asarray(scales),
                jnp.float32(w), interpret=interpret,
            )
        return _flat_dequant_fold_jnp(
            acc, jnp.asarray(data), jnp.asarray(scales), jnp.float32(w)
        )
    raise ValueError(f"unknown compressed codec {update.codec!r}")


def _leaf_nbytes(leaf: Any) -> int:
    nbytes = getattr(leaf, "nbytes", None)
    return int(nbytes) if nbytes is not None else int(np.asarray(leaf).nbytes)


# ---------------------------------------------------------------------------
# Partial sums (hierarchical aggregation)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PartialSum:
    """One aggregator's exported partial fold — the hierarchy wire unit.

    ``acc`` is the BLOCK-padded fp32 delta accumulator (the exact buffer
    a flat-mode :class:`StreamingAggregator` holds: ``sum_i w_i *
    (update_i - base)``, zero-padded to the Pallas tile multiple), so a
    parent engine folds it with one elementwise add and regional /
    parent results compose to the same weighted average the flat fold
    computes.  ``wsum`` / ``n_clients`` are the region's raw weight
    total and client count; ``plan_signature`` pins the model structure
    and ``base_round`` the global weights the deltas were taken against
    — :meth:`StreamingAggregator.fold_partial` validates both, because a
    partial folded against a different structure or base is silent
    corruption."""

    acc: Any
    wsum: float
    n_clients: int
    plan_signature: str
    base_round: Optional[int] = None
    region_id: str = ""

    @property
    def wire_bytes(self) -> int:
        """Bytes a parent link carries for this partial (the fp32 acc)."""
        return _leaf_nbytes(self.acc)


@dataclasses.dataclass(frozen=True)
class StructuredPartialSum:
    """A structured aggregator's exported fold: one PartialSum per group.

    Groups no silo in the region contributed to are *omitted* — absent
    silos contribute no weight to a group, and that has to survive the
    hierarchy hop (a zero-accumulator partial with nonzero wsum would
    drag the group toward the base).  ``schema_signature`` pins the
    exact partition; each group's inner :class:`PartialSum` carries its
    own group-plan signature, and the parent validates both."""

    groups: Tuple[Tuple[str, PartialSum], ...]
    schema_signature: str
    n_clients: int
    base_round: Optional[int] = None
    region_id: str = ""

    @property
    def wire_bytes(self) -> int:
        """Bytes a parent link carries (sum of the per-group fp32 accs)."""
        return sum(p.wire_bytes for _, p in self.groups)

    @property
    def wsum(self) -> float:
        """Round-weight proxy for bus/event accounting: the largest
        per-group weight total (each group normalizes independently, so
        there is no single scalar — the max is what a fully-present silo
        cohort contributed)."""
        return max((p.wsum for _, p in self.groups), default=0.0)

    def group_wsums(self) -> Dict[str, float]:
        return {n: p.wsum for n, p in self.groups}


class StreamingAggregator:
    """Running weighted accumulation: fold clients in as they land.

    ``add(params, weight)`` costs one fused pass over that client's
    bytes and keeps only a single fp32 accumulator (donated in place),
    so asynchronously arriving silos are aggregated in O(L) memory
    rather than O(N·L).  ``result()`` normalizes by the running weight
    total, casts back to the model dtypes, consumes the accumulator, and
    resets all per-fold state so a reused aggregator starts a fresh fold.

    With ``base`` (the round's global weights) the aggregator runs in
    *flat/delta mode*: one padded fp32 vector accumulator, every update
    folded as ``w * (update - base)`` and the result read out as
    ``base + acc / wsum`` — numerically the same weighted average (the
    base cancels exactly), but able to fold
    :class:`~repro.federated.compression.CompressedUpdate` payloads
    (int8 / fp16 / top-k deltas) directly via the fused Pallas
    dequantize-and-fold kernel, never materializing a dense fp32 update.

    The base survives ``result()`` so a flat-mode aggregator can be
    reused — but a *reused* aggregator folding the NEXT round's deltas
    must first :meth:`rebase` onto that round's global weights:
    compressed deltas are meaningless against a stale base.  Construct
    with ``base_round`` (or via ``streaming(base=..., base_round=...)``)
    to have :meth:`add_compressed` enforce the match against each
    update's own ``base_round`` tag.
    """

    def __init__(
        self,
        engine: Optional[AggregationEngine] = None,
        base: Any = None,
        base_round: Optional[int] = None,
    ) -> None:
        self._engine = engine
        self._plan: Optional[RavelPlan] = None
        self._base_flat: Optional[Any] = None
        self._padded_len = 0
        self.base_round: Optional[int] = None
        if base is not None:
            from repro.kernels.fedavg_reduce import BLOCK as _block
            self._plan = plan_for(base)
            self._base_flat = self._plan.flatten(base)
            self._padded_len = -(-self._plan.total_elems // _block) * _block
            self.base_round = base_round
        elif base_round is not None:
            raise ValueError(
                "base_round tags a delta base: pass base= too"
            )
        self._acc: Any = None
        self._acc_flat: Optional[Any] = None
        self._dtypes: Optional[List[Any]] = None
        self._treedef: Any = None
        self._shapes: Optional[Tuple[Tuple[int, ...], ...]] = None
        self._wsum = 0.0
        self.n_clients = 0

    def _reset(self) -> None:
        """Clear per-fold state (`result()` calls this); the base/plan
        are construction-time configuration and survive for reuse —
        callers starting a NEW round on a reused flat-mode aggregator
        must :meth:`rebase` onto that round's global weights first."""
        self._acc = None
        self._acc_flat = None
        self._dtypes = None
        self._treedef = None
        self._shapes = None
        self._wsum = 0.0
        self.n_clients = 0

    def _ensure_flat_acc(self) -> Any:
        if self._acc_flat is None:
            self._acc_flat = jnp.zeros(self._padded_len, jnp.float32)
        return self._acc_flat

    @property
    def mid_fold(self) -> bool:
        """True while a fold is accumulating (clients added, no result yet)."""
        return self.n_clients > 0 or self._acc is not None or self._acc_flat is not None

    def rebase(self, base: Any, base_round: Optional[int] = None) -> None:
        """Re-anchor a reused flat-mode aggregator on a new round's base.

        The fix for the stale-base reuse bug: ``_base_flat`` survives
        ``_reset()`` by design (construction-time configuration), so a
        flat-mode aggregator reused for the next round would silently
        fold that round's compressed deltas against the *previous*
        round's global weights.  Call ``rebase(new_global_params,
        base_round=r)`` between rounds instead of rebuilding the
        aggregator; the new base must have the same pytree structure,
        and rebasing mid-fold is rejected (the accumulator holds deltas
        against the old base)."""
        if self._plan is None or self._base_flat is None:
            raise ValueError(
                "rebase() applies to flat/delta mode: construct the "
                "aggregator with streaming(base=global_params) first"
            )
        if self.mid_fold:
            raise ValueError(
                "cannot rebase mid-fold: the accumulator holds deltas "
                "against the current base — call result() (or "
                "export_partial()) first"
            )
        plan = plan_for(base)
        if plan.signature != self._plan.signature:
            mismatch = _first_structure_mismatch(
                self._plan.treedef, self._plan.shapes, base
            )
            raise StructureMismatchError(
                "rebase() base does not match the aggregator's plan"
                + (f" at leaf {mismatch[0]!r}: {mismatch[1]}" if mismatch else ""),
                path=mismatch[0] if mismatch else None,
            )
        self._plan = plan
        self._base_flat = plan.flatten(base)
        self.base_round = base_round

    def _check_structure(self, params: Any, client_id: Optional[str]) -> None:
        if self._plan is not None:
            ref_treedef, ref_shapes = self._plan.treedef, self._plan.shapes
        elif self._treedef is not None and self._shapes is not None:
            ref_treedef, ref_shapes = self._treedef, self._shapes
        else:
            return
        mismatch = _first_structure_mismatch(ref_treedef, ref_shapes, params)
        if mismatch is not None:
            _raise_structure_mismatch(mismatch, client_id)

    def add(
        self,
        params: Any,
        weight: float,
        block: bool = False,
        wire_bytes: Optional[int] = None,
        client_id: Optional[str] = None,
    ) -> None:
        """Fold one client in; ``block=True`` waits for the fused
        accumulate to finish (the async round engine uses it to measure
        the true per-fold cost instead of dispatch latency).
        ``wire_bytes`` is the transport frame size when it differs from
        the dense in-memory bytes (compressed arrivals); compressed
        payloads themselves route to :meth:`add_compressed`.
        ``client_id`` names the silo in structure-mismatch errors."""
        from repro.federated.compression import CompressedUpdate
        if isinstance(params, CompressedUpdate):
            self.add_compressed(
                params, weight, block=block, wire_bytes=wire_bytes,
                client_id=client_id,
            )
            return
        w = float(weight)
        if w < 0:
            raise ValueError("client weight must be non-negative")
        self._check_structure(params, client_id)
        if self._base_flat is not None:
            assert self._plan is not None
            flat = self._plan.flatten(params)
            acc = self._ensure_flat_acc()
            self._acc_flat = _flat_delta_fold(
                acc, flat, self._base_flat, jnp.float32(w)
            )
            folded = self._acc_flat
        elif self._acc is None:
            leaves, self._treedef = jax.tree.flatten(params)
            # Pin accumulator dtypes from the first client's *concrete*
            # leaf dtypes (what jnp.asarray actually stores) — never
            # jnp.result_type, which weak-type-promotes Python-scalar
            # and numpy-default leaves past what jax will materialize.
            self._dtypes = [jnp.asarray(l).dtype for l in leaves]
            # Pin the structure too: every later client is validated
            # against this treedef + these leaf shapes (a mismatch used
            # to surface as an opaque tree.map error or a silent
            # broadcast).
            self._shapes = tuple(tuple(np.shape(l)) for l in leaves)
            self._acc = _scale_tree(params, jnp.float32(w))
            folded = self._acc
        else:
            self._acc = _accum_tree(self._acc, params, jnp.float32(w))
            folded = self._acc
        if block:
            jax.block_until_ready(folded)
        self._wsum += w
        self.n_clients += 1
        if self._engine is not None:
            nbytes = sum(_leaf_nbytes(l) for l in jax.tree.leaves(params))
            self._engine.stats.record(nbytes, wire_bytes)

    def add_compressed(
        self,
        update: Any,
        weight: float,
        block: bool = False,
        wire_bytes: Optional[int] = None,
        client_id: Optional[str] = None,
    ) -> None:
        """Fold one compressed delta straight into the fp32 accumulator.

        int8 / fp16 payloads go through the fused Pallas
        ``dequant_fold`` kernel (or its jitted fallback on einsum-tier
        backends) — one pass over the quantized bytes, no dense fp32
        intermediate; top-k payloads fold with a donated sparse scatter.

        An update tagged with a ``base_round`` must match the
        aggregator's own base-round tag: the payload is a delta against
        that specific round's global weights, and folding it against any
        other base silently corrupts the average (the stale-base reuse
        bug) — :meth:`rebase` the aggregator between rounds.
        """
        if self._base_flat is None or self._plan is None:
            raise ValueError(
                "compressed updates need a delta base: construct the "
                "aggregator with streaming(base=global_params)"
            )
        update_round = getattr(update, "base_round", None)
        if update_round is not None and update_round != self.base_round:
            who = f" from client {client_id!r}" if client_id is not None else ""
            raise ValueError(
                f"compressed update{who} was encoded against base round "
                f"{update_round}, but the aggregator's base is "
                f"{'untagged' if self.base_round is None else f'round {self.base_round}'}"
                " — rebase(new_base, base_round=...) the aggregator onto "
                "the update's round before folding"
            )
        if update.total_elems != self._plan.total_elems:
            raise ValueError(
                f"compressed update has {update.total_elems} elements; "
                f"the model has {self._plan.total_elems}"
            )
        w = float(weight)
        if w < 0:
            raise ValueError("client weight must be non-negative")
        acc = self._ensure_flat_acc()
        interp = self._engine.interpret if self._engine is not None else None
        self._acc_flat = _fold_compressed_into(
            acc, update, w, self._padded_len, self._use_pallas(), interp
        )
        if block:
            jax.block_until_ready(self._acc_flat)
        self._wsum += w
        self.n_clients += 1
        if self._engine is not None:
            wire = wire_bytes if wire_bytes is not None else update.wire_bytes
            self._engine.stats.record(update.dense_bytes, wire)

    def _use_pallas(self) -> bool:
        if self._engine is not None:
            return bool(self._engine.use_pallas)
        return jax.default_backend() == "tpu"

    def add_stale(
        self,
        params: Any,
        weight: float,
        stale_rounds: int,
        discount: float,
        block: bool = False,
        client_id: Optional[str] = None,
    ) -> float:
        """Fold a carried-over (stale) update with a staleness-discounted
        weight ``weight * discount**stale_rounds``; returns the effective
        weight that entered the average."""
        if stale_rounds < 1:
            raise ValueError("a stale fold must be at least one round late")
        if not 0.0 <= discount <= 1.0:
            raise ValueError("staleness discount must be in [0, 1]")
        w_eff = float(weight) * float(discount) ** int(stale_rounds)
        self.add(params, w_eff, block=block, client_id=client_id)
        return w_eff

    def fold_carry(
        self,
        buffer: CarryOverBuffer,
        round_idx: int,
        discount: float,
        block: bool = False,
    ) -> List[Tuple[CarryEntry, float]]:
        """Drain a :class:`CarryOverBuffer` into the accumulator.

        Every parked entry is folded with its staleness discount applied
        (age = ``round_idx - origin_round`` rounds, at least 1); returns
        the ``(entry, effective_weight)`` pairs so callers can account the
        raw-vs-discounted weights (weight conservation audits)."""
        folded: List[Tuple[CarryEntry, float]] = []
        for entry in buffer.drain():
            w_eff = self.add_stale(
                entry.params, entry.weight, entry.age_at(round_idx),
                discount, block=block, client_id=entry.client_id,
            )
            folded.append((entry, w_eff))
        return folded

    # -- hierarchy: partial-sum export / fold -------------------------------
    def export_partial(self, region_id: str = "") -> PartialSum:
        """Consume the fold as a :class:`PartialSum` instead of params.

        The regional half of the hierarchy: the padded accumulator,
        weight total, and client count leave as one composable unit (the
        base is NOT applied — the parent holds the same base and applies
        it once at finalize).  Flat/delta mode only: partial sums
        compose only against a shared base.  Like :meth:`result`, the
        per-fold state is consumed."""
        if self._plan is None or self._base_flat is None:
            raise ValueError(
                "export_partial() requires flat/delta mode: partial sums "
                "compose only against a shared base — construct the "
                "aggregator with streaming(base=global_params)"
            )
        if self.n_clients == 0:
            raise ValueError("no clients have been added")
        partial = PartialSum(
            acc=self._ensure_flat_acc(),
            wsum=self._wsum,
            n_clients=self.n_clients,
            plan_signature=self._plan.signature,
            base_round=self.base_round,
            region_id=region_id,
        )
        self._reset()
        if self._engine is not None:
            self._engine.stats.n_calls += 1
        return partial

    def fold_partial(self, partial: PartialSum, block: bool = False) -> None:
        """Fold a regional :class:`PartialSum` into this accumulator.

        One donated elementwise add over the padded fp32 buffers —
        weighted partial sums compose associatively, so a parent folding
        R regional partials computes exactly the flat engine's
        ``sum_i w_i * (update_i - base)`` over all N clients.  The
        partial's plan signature and base-round tag must match this
        aggregator's (folding a partial taken against a different
        structure or base is silent corruption)."""
        if self._plan is None or self._base_flat is None:
            raise ValueError(
                "fold_partial() requires flat/delta mode: construct the "
                "aggregator with streaming(base=global_params)"
            )
        if partial.n_clients < 1:
            raise ValueError("a partial sum must carry at least one client")
        if partial.wsum < 0:
            raise ValueError("partial weight total must be non-negative")
        if partial.plan_signature != self._plan.signature:
            raise StructureMismatchError(
                f"partial sum from region {partial.region_id!r} was taken "
                f"against plan {partial.plan_signature}, but this "
                f"aggregator's plan is {self._plan.signature}",
                client_id=partial.region_id or None,
            )
        if partial.base_round != self.base_round:
            raise ValueError(
                f"partial sum from region {partial.region_id!r} was "
                f"accumulated against base round {partial.base_round}, but "
                f"the aggregator's base is round {self.base_round}"
            )
        other = jnp.asarray(partial.acc, jnp.float32)
        acc = self._ensure_flat_acc()
        if other.shape != acc.shape:
            raise ValueError(
                f"partial accumulator has shape {other.shape}; the parent's "
                f"padded accumulator is {acc.shape}"
            )
        self._acc_flat = _flat_partial_fold(acc, other)
        if block:
            jax.block_until_ready(self._acc_flat)
        self._wsum += float(partial.wsum)
        self.n_clients += int(partial.n_clients)
        if self._engine is not None:
            nbytes = _leaf_nbytes(other)
            self._engine.stats.record(nbytes, nbytes)

    def result(self) -> Any:
        if self._acc is None and self._acc_flat is None:
            raise ValueError("no clients have been added")
        if self._wsum <= 0:
            raise ValueError("aggregation weights must sum to a positive value")
        if self._acc_flat is not None:
            assert self._plan is not None and self._base_flat is not None
            vec = _flat_finalize(
                self._acc_flat, self._base_flat, jnp.float32(1.0 / self._wsum)
            )
            out = self._plan.unflatten(vec)
        else:
            acc = _scale_acc(self._acc, jnp.float32(1.0 / self._wsum))
            leaves = jax.tree.leaves(acc)
            assert self._dtypes is not None
            outs = [l.astype(dt) for l, dt in zip(leaves, self._dtypes)]
            out = jax.tree.unflatten(self._treedef, outs)
        # Consume: the accumulator was donated, and every per-fold field
        # (_wsum, n_clients, _dtypes, _treedef) must go with it — stale
        # normalizer state would silently double-count on reuse.
        self._reset()
        if self._engine is not None:
            self._engine.stats.n_calls += 1
        return out


class StructuredStreamingAggregator:
    """Per-group streaming folds under an :class:`UpdateSchema`.

    Each named group keeps its own padded fp32 delta accumulator and its
    own running weight total, so silos may ship any subset of the groups
    — a silo absent from a group contributes no weight to it, and each
    element of the finalized model normalizes by the weight total of the
    groups that actually cover it (overlapping groups sum their
    totals).  Elements no present group covers keep the base exactly.

    ``add`` accepts three payload shapes per client:

    * a :class:`~repro.federated.compression.StructuredUpdate` (the wire
      form) — per-group raw fp32 *values* or per-group compressed
      *deltas* against the aggregator's base;
    * a plain mapping ``{group name: payload}`` with the same per-group
      semantics (a compact fp32 vector is the group's raw values, a
      ``CompressedUpdate`` a delta);
    * a full model pytree — structure-validated, then sliced into every
      group (the dense degenerate case).

    A full-coverage schema (every leaf in exactly one group) with every
    client present in every group folds *bit-for-bit* identically to the
    dense flat/delta path: the per-group folds run the same jitted ops
    over the same values in the same order, the per-element numerator is
    placed by an exact scatter into zeros, and the per-leaf normalizer
    rounds ``1/wsum`` exactly as the dense finalize does.
    """

    def __init__(
        self,
        engine: Optional[AggregationEngine],
        schema: Union[UpdateSchema, ResolvedSchema, Mapping[str, Any]],
        base: Any,
        base_round: Optional[int] = None,
    ) -> None:
        if base is None:
            raise ValueError(
                "structured aggregation needs the round's global weights: "
                "pass base= (per-group deltas and absent groups are both "
                "defined relative to it)"
            )
        self._engine = engine
        if isinstance(schema, ResolvedSchema):
            self._schema = schema
        else:
            self._schema = as_update_schema(
                cast(Union[UpdateSchema, Mapping[str, Any]], schema)
            ).resolve(base)  # type: ignore[union-attr]
        self._plan = self._schema.plan
        self._base_flat = self._plan.flatten(base)
        self._group_base: Dict[str, Any] = {
            name: gp.flatten(base) for name, gp in self._schema.groups
        }
        self.base_round = base_round
        self._accs: Dict[str, Any] = {}
        self._wsums: Dict[str, float] = {n: 0.0 for n in self._schema.group_names}
        self._counts: Dict[str, int] = {n: 0 for n in self._schema.group_names}
        self.n_clients = 0

    @property
    def schema(self) -> ResolvedSchema:
        return self._schema

    @property
    def mid_fold(self) -> bool:
        return self.n_clients > 0 or bool(self._accs)

    def group_wsums(self) -> Dict[str, float]:
        """Per-group running weight totals (weight-conservation audits)."""
        return dict(self._wsums)

    def group_counts(self) -> Dict[str, int]:
        """Per-group client counts (a silo counts once per group present)."""
        return dict(self._counts)

    def _reset(self) -> None:
        self._accs = {}
        self._wsums = {n: 0.0 for n in self._schema.group_names}
        self._counts = {n: 0 for n in self._schema.group_names}
        self.n_clients = 0

    def rebase(self, base: Any, base_round: Optional[int] = None) -> None:
        """Re-anchor on a new round's global weights (see
        :meth:`StreamingAggregator.rebase` for why mid-fold is rejected)."""
        if self.mid_fold:
            raise ValueError(
                "cannot rebase mid-fold: the accumulators hold deltas "
                "against the current base — call result() (or "
                "export_partial()) first"
            )
        plan = plan_for(base)
        if plan.signature != self._plan.signature:
            mismatch = _first_structure_mismatch(
                self._plan.treedef, self._plan.shapes, base
            )
            raise StructureMismatchError(
                "rebase() base does not match the aggregator's plan"
                + (f" at leaf {mismatch[0]!r}: {mismatch[1]}" if mismatch else ""),
                path=mismatch[0] if mismatch else None,
            )
        self._base_flat = plan.flatten(base)
        self._group_base = {
            name: gp.flatten(base) for name, gp in self._schema.groups
        }
        self.base_round = base_round

    def _ensure_acc(self, name: str) -> Any:
        acc = self._accs.get(name)
        if acc is None:
            acc = jnp.zeros(self._schema.group(name).padded_len, jnp.float32)
            self._accs[name] = acc
        return acc

    def _check_base_round(
        self, update_round: Optional[int], client_id: Optional[str]
    ) -> None:
        if update_round is not None and update_round != self.base_round:
            who = f" from client {client_id!r}" if client_id is not None else ""
            raise ValueError(
                f"structured update{who} was encoded against base round "
                f"{update_round}, but the aggregator's base is "
                f"{'untagged' if self.base_round is None else f'round {self.base_round}'}"
                " — rebase(new_base, base_round=...) the aggregator onto "
                "the update's round before folding"
            )

    def _payload_items(
        self, params: Any, client_id: Optional[str]
    ) -> Tuple[List[Tuple[str, Any]], Optional[int]]:
        """Normalize one client's payload to [(group, payload)] + wire bytes."""
        from repro.federated.compression import CompressedUpdate, StructuredUpdate
        if isinstance(params, StructuredUpdate):
            if params.schema_signature != self._schema.signature:
                who = (f" from client {client_id!r}"
                       if client_id is not None else "")
                raise ValueError(
                    f"structured update{who} was encoded under schema "
                    f"{params.schema_signature}, but the aggregator's "
                    f"schema is {self._schema.signature}"
                )
            self._check_base_round(params.base_round, client_id)
            return list(params.groups), params.wire_bytes
        # A plain mapping is a {group: payload} dict only when its keys are
        # all schema group names and its values are wire payloads (compact
        # vectors / CompressedUpdates) — a model pytree whose top level is
        # a dict of sub-trees falls through to the full-tree branch.
        if isinstance(params, Mapping) and params and all(
            k in self._wsums
            and not isinstance(v, Mapping)
            and (isinstance(v, CompressedUpdate)
                 or np.ndim(v) == 1)
            for k, v in params.items()
        ):
            return list(params.items()), None
        # A full model pytree: validate structure, slice every group out.
        mismatch = _first_structure_mismatch(
            self._plan.treedef, self._plan.shapes, params
        )
        if mismatch is not None:
            _raise_structure_mismatch(mismatch, client_id)
        return (
            [(name, gp.flatten(params)) for name, gp in self._schema.groups],
            None,
        )

    def add(
        self,
        params: Any,
        weight: float,
        block: bool = False,
        wire_bytes: Optional[int] = None,
        client_id: Optional[str] = None,
    ) -> None:
        """Fold one client's (possibly partial) structured update in.

        ``weight`` applies to every group the client shipped; groups the
        client omitted see neither the update nor the weight."""
        from repro.federated.compression import CompressedUpdate
        w = float(weight)
        if w < 0:
            raise ValueError("client weight must be non-negative")
        items, payload_wire = self._payload_items(params, client_id)
        if not items:
            raise ValueError("a structured update must carry at least one group")
        folded_bytes = 0
        last: Any = None
        for name, payload in items:
            if name not in self._wsums:
                raise ValueError(
                    f"update carries unknown group {name!r}; the schema's "
                    f"groups are {list(self._schema.group_names)}"
                )
            gp = self._schema.group(name)
            acc = self._ensure_acc(name)
            if isinstance(payload, CompressedUpdate):
                self._check_base_round(payload.base_round, client_id)
                if payload.total_elems != gp.total_elems:
                    raise ValueError(
                        f"group {name!r} update has {payload.total_elems} "
                        f"elements; the group has {gp.total_elems}"
                    )
                interp = (self._engine.interpret
                          if self._engine is not None else None)
                self._accs[name] = _fold_compressed_into(
                    acc, payload, w, gp.padded_len, self._use_pallas(), interp
                )
                folded_bytes += payload.dense_bytes
            else:
                vec = jnp.asarray(payload, jnp.float32).reshape(-1)
                if vec.shape[0] != gp.total_elems:
                    raise ValueError(
                        f"group {name!r} payload has {vec.shape[0]} "
                        f"elements; the group has {gp.total_elems}"
                    )
                self._accs[name] = _flat_delta_fold(
                    acc, vec, self._group_base[name], jnp.float32(w)
                )
                folded_bytes += gp.total_elems * 4
            last = self._accs[name]
            self._wsums[name] += w
            self._counts[name] += 1
        if block and last is not None:
            jax.block_until_ready(last)
        self.n_clients += 1
        if self._engine is not None:
            wire = wire_bytes if wire_bytes is not None else payload_wire
            self._engine.stats.record(folded_bytes, wire)

    def add_stale(
        self,
        params: Any,
        weight: float,
        stale_rounds: int,
        discount: float,
        block: bool = False,
        client_id: Optional[str] = None,
    ) -> float:
        """Staleness-discounted structured fold (mirrors the dense rule)."""
        if stale_rounds < 1:
            raise ValueError("a stale fold must be at least one round late")
        if not 0.0 <= discount <= 1.0:
            raise ValueError("staleness discount must be in [0, 1]")
        w_eff = float(weight) * float(discount) ** int(stale_rounds)
        self.add(params, w_eff, block=block, client_id=client_id)
        return w_eff

    def fold_carry(
        self,
        buffer: CarryOverBuffer,
        round_idx: int,
        discount: float,
        block: bool = False,
    ) -> List[Tuple[CarryEntry, float]]:
        """Drain parked entries with the age discount (dense parity)."""
        folded: List[Tuple[CarryEntry, float]] = []
        for entry in buffer.drain():
            w_eff = self.add_stale(
                entry.params, entry.weight, entry.age_at(round_idx),
                discount, block=block, client_id=entry.client_id,
            )
            folded.append((entry, w_eff))
        return folded

    def _use_pallas(self) -> bool:
        if self._engine is not None:
            return bool(self._engine.use_pallas)
        return jax.default_backend() == "tpu"

    # -- hierarchy: per-group partial export / fold --------------------------
    def export_partial(self, region_id: str = "") -> StructuredPartialSum:
        """Consume the fold as one :class:`PartialSum` per present group.

        Groups no client contributed to are omitted entirely — absent
        silos contribute no weight, and the parent must see that."""
        if self.n_clients == 0:
            raise ValueError("no clients have been added")
        groups: List[Tuple[str, PartialSum]] = []
        for name, gp in self._schema.groups:
            if self._counts[name] == 0:
                continue
            groups.append((name, PartialSum(
                acc=self._ensure_acc(name),
                wsum=self._wsums[name],
                n_clients=self._counts[name],
                plan_signature=gp.signature,
                base_round=self.base_round,
                region_id=region_id,
            )))
        partial = StructuredPartialSum(
            groups=tuple(groups),
            schema_signature=self._schema.signature,
            n_clients=self.n_clients,
            base_round=self.base_round,
            region_id=region_id,
        )
        self._reset()
        if self._engine is not None:
            self._engine.stats.n_calls += 1
        return partial

    def fold_partial(
        self, partial: StructuredPartialSum, block: bool = False
    ) -> None:
        """Fold a regional :class:`StructuredPartialSum` in, per group."""
        if partial.schema_signature != self._schema.signature:
            raise StructureMismatchError(
                f"structured partial from region {partial.region_id!r} was "
                f"taken under schema {partial.schema_signature}, but this "
                f"aggregator's schema is {self._schema.signature}",
                client_id=partial.region_id or None,
            )
        if partial.base_round != self.base_round:
            raise ValueError(
                f"structured partial from region {partial.region_id!r} was "
                f"accumulated against base round {partial.base_round}, but "
                f"the aggregator's base is round {self.base_round}"
            )
        if partial.n_clients < 1:
            raise ValueError("a partial sum must carry at least one client")
        last: Any = None
        total_bytes = 0
        for name, p in partial.groups:
            if name not in self._wsums:
                raise ValueError(
                    f"structured partial carries unknown group {name!r}"
                )
            gp = self._schema.group(name)
            if p.plan_signature != gp.signature:
                raise StructureMismatchError(
                    f"group {name!r} partial was taken against plan "
                    f"{p.plan_signature}, but this aggregator's group plan "
                    f"is {gp.signature}",
                    client_id=partial.region_id or None,
                )
            if p.wsum < 0:
                raise ValueError("partial weight total must be non-negative")
            other = jnp.asarray(p.acc, jnp.float32)
            acc = self._ensure_acc(name)
            if other.shape != acc.shape:
                raise ValueError(
                    f"group {name!r} partial accumulator has shape "
                    f"{other.shape}; the parent's is {acc.shape}"
                )
            self._accs[name] = _flat_partial_fold(acc, other)
            last = self._accs[name]
            self._wsums[name] += float(p.wsum)
            self._counts[name] += int(p.n_clients)
            total_bytes += _leaf_nbytes(other)
        if block and last is not None:
            jax.block_until_ready(last)
        self.n_clients += int(partial.n_clients)
        if self._engine is not None:
            self._engine.stats.record(total_bytes, total_bytes)

    def result(self) -> Any:
        """Finalize: scatter per-group numerators into one model-sized
        vector, normalize each element by its covering groups' weight
        total, and read out ``base + numerator / wsum`` per element."""
        if self.n_clients == 0:
            raise ValueError("no clients have been added")
        if not any(w > 0 for w in self._wsums.values()):
            raise ValueError("aggregation weights must sum to a positive value")
        num = jnp.zeros(self._plan.total_elems, jnp.float32)
        for name, gp in self._schema.groups:
            if self._counts[name] == 0:
                continue
            acc = self._ensure_acc(name)
            num = _flat_group_scatter(
                num, jnp.asarray(gp.offsets), acc[: gp.total_elems]
            )
        # Per-element normalizer, built host-side from the per-leaf
        # coverage map: each leaf's denominator is the sum (schema
        # order, Python-float accumulation — the dense path's exact
        # arithmetic) of its covering groups' weight totals, skipping
        # groups nobody shipped.  Zero-weight elements keep the base.
        inv_np = np.zeros(self._plan.total_elems, np.float32)
        off = 0
        present_wsums = {
            n: w for n, w in self._wsums.items() if self._counts[n] > 0
        }
        for i, size in enumerate(self._plan.sizes):
            wsum_leaf = 0.0
            for name in self._schema.leaf_groups[i]:
                if name in present_wsums:
                    wsum_leaf += present_wsums[name]
            if wsum_leaf > 0:
                inv_np[off:off + size] = np.float32(1.0 / wsum_leaf)
            off += size
        vec = _flat_finalize_vec(num, self._base_flat, jnp.asarray(inv_np))
        out = self._plan.unflatten(vec)
        self._reset()
        if self._engine is not None:
            self._engine.stats.n_calls += 1
        return out


# ---------------------------------------------------------------------------
# Cost-accounting hook (simulator integration)
# ---------------------------------------------------------------------------

def make_measured_aggreg_fn(
    env: Any,
    bytes_per_round: int,
    gb_per_s: float,
    base_vm_id: Optional[str] = None,
) -> Callable[[str], float]:
    """Build a `CostModel.t_aggreg` override from a measured reduce rate.

    ``bytes_per_round`` is the dense-equivalent byte volume the server
    reduces each round (N clients x model bytes, e.g.
    `AggStats.last_folded_bytes` — the reduce runs over dequantized fp32
    regardless of what crossed the wire, so folded, not wire, bytes set
    the aggregation time);
    ``gb_per_s`` the measured engine bandwidth (benchmarks/aggregation_bench
    reports it per shape).  The time scales with each VM's instance
    slowdown exactly like the paper's `aggreg_bl` baseline does.
    """
    if gb_per_s <= 0:
        raise ValueError("gb_per_s must be positive")
    base_s = bytes_per_round / (gb_per_s * 1e9)
    base_slow = env.inst_slowdown(base_vm_id) if base_vm_id is not None else 1.0

    def t_aggreg(vm_id: str) -> float:
        return base_s * env.inst_slowdown(vm_id) / base_slow

    return t_aggreg
