"""Fused FedAvg aggregation engine — the server's per-round hot path.

The seed implementation (`aggregation.fedavg`, kept as the correctness
oracle) reduces N client pytrees with a per-leaf Python loop of N
multiply-adds, each dispatched op-by-op and materializing N fp32
temporaries per leaf.  At cross-silo model sizes this is a pure
memory-bound streaming reduce, so the engine's job is to touch every
client byte exactly once per round.

Dispatch hierarchy (backend-aware, detected once per engine):

  TPU   — flatten-once: each client tree is raveled through a cached
          :class:`RavelPlan` (treedef / shape-layout computed once per
          model structure, reused every round — no per-round retracing
          or re-padding) into one contiguous fp32 ``(N, L)`` buffer,
          reduced by the Pallas ``fedavg_reduce`` kernel (compiled, not
          interpreted), with the stacked buffer *donated* so XLA reuses
          the HBM instead of doubling peak memory.
  CPU/GPU — one jitted fused reduce over the client trees: XLA fuses the
          weighted multiply-add chain per leaf into a single pass over
          the inputs (a dot over the client axis), with no per-round
          Python loop and no ``(N, L)`` materialization.  For buffers
          that are *already* stacked ``(N, L)`` (pod replica stacks,
          benchmarks) the reduce is a single fp32-accumulated
          ``jnp.einsum``.

A chunked mode (`reduce_flat(..., chunk_elems=...)`) streams the reduce
in O(N·block) rather than O(N·L) working memory, and
:class:`StreamingAggregator` folds clients in *as they land* (running
weighted accumulation with an O(L) donated-in-place accumulator), so
asynchronously arriving silos never require holding all N models.

Deadline-driven partial rounds (see :mod:`repro.federated.async_server`)
park updates that miss a round's ``T_round`` in a :class:`CarryOverBuffer`;
the next round's :class:`StreamingAggregator` drains it first, folding each
late silo with a staleness-discounted weight (``StreamingAggregator
.add_stale`` / ``fold_carry``), so no silo's contribution is ever dropped.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Ravel plans: flatten/unflatten compiled once per model structure
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RavelPlan:
    """Cached flatten/unflatten layout for one pytree structure.

    ``flatten_stack`` ravels a *list* of N structurally-identical trees
    into one contiguous fp32 ``(N, L)`` buffer in a single jitted call;
    ``unflatten`` restores an ``(L,)`` vector to the original treedef,
    shapes, and per-leaf dtypes.  Both are traced exactly once per model
    structure (the plan is cached), so the per-round cost is pure data
    movement.
    """

    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    sizes: Tuple[int, ...]
    total_elems: int
    flatten: Callable[[Any], jnp.ndarray]
    flatten_stack: Callable[[Sequence[Any]], jnp.ndarray]
    unflatten: Callable[[jnp.ndarray], Any]


_PLAN_CACHE: Dict[Any, RavelPlan] = {}


def _structure_key(tree: Any) -> Any:
    leaves, treedef = jax.tree.flatten(tree)
    return (
        treedef,
        tuple(tuple(l.shape) for l in leaves),
        tuple(jnp.result_type(l).name for l in leaves),
    )


def plan_for(tree: Any) -> RavelPlan:
    """Return the (cached) RavelPlan for ``tree``'s structure."""
    key = _structure_key(tree)
    plan = _PLAN_CACHE.get(key)
    if plan is not None:
        return plan

    leaves, treedef = jax.tree.flatten(tree)
    if not leaves:
        raise ValueError("cannot build a ravel plan for an empty pytree")
    shapes = tuple(tuple(l.shape) for l in leaves)
    dtypes = tuple(jnp.result_type(l) for l in leaves)
    sizes = tuple(int(np.prod(s)) if s else 1 for s in shapes)
    total = int(sum(sizes))

    @jax.jit
    def flatten(t):
        ls = jax.tree.leaves(t)
        return jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in ls])

    @jax.jit
    def flatten_stack(trees):
        rows = [
            jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in jax.tree.leaves(t)])
            for t in trees
        ]
        return jnp.stack(rows)

    @jax.jit
    def unflatten(vec):
        outs = []
        off = 0
        for shape, dtype, size in zip(shapes, dtypes, sizes):
            outs.append(vec[off:off + size].reshape(shape).astype(dtype))
            off += size
        return jax.tree.unflatten(treedef, outs)

    plan = RavelPlan(
        treedef=treedef, shapes=shapes, dtypes=dtypes, sizes=sizes,
        total_elems=total, flatten=flatten, flatten_stack=flatten_stack,
        unflatten=unflatten,
    )
    _PLAN_CACHE[key] = plan
    return plan


# ---------------------------------------------------------------------------
# Fused flat reduces
# ---------------------------------------------------------------------------

def _dot_reduce(stacked: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """(N, L) x (N,) -> (L,): single fp32-accumulated contraction.

    ``w`` must already be normalized."""
    out = jnp.einsum("n,nl->l", w, stacked, preferred_element_type=jnp.float32)
    return out.astype(stacked.dtype)


def _pallas_flat_reduce(stacked, weights, interpret):
    from repro.kernels.fedavg_reduce import fedavg_reduce as _kernel
    return _kernel(stacked, weights, interpret=interpret)


def fused_stacked_tree_reduce(stacked: Any, weights: jnp.ndarray) -> Any:
    """Traceable FedAvg over a pytree with a leading client/pod axis.

    Flattens every leaf of the replica stack into one ``(N, L)`` buffer
    and reduces it with a single fused contraction (Pallas kernel on
    TPU, fp32 einsum elsewhere) instead of a per-leaf ``tree.map`` —
    this is the fused call `pod_fedavg` lowers inside `fl_round_step`.
    """
    leaves, treedef = jax.tree.flatten(stacked)
    if not leaves:
        return stacked
    n = leaves[0].shape[0]
    w = weights.astype(jnp.float32)
    flat = jnp.concatenate(
        [l.reshape(n, -1).astype(jnp.float32) for l in leaves], axis=1
    )
    if jax.default_backend() == "tpu":
        red = _pallas_flat_reduce(flat, w, interpret=False)
    else:
        red = _dot_reduce(flat, w / jnp.sum(w))
    outs = []
    off = 0
    for l in leaves:
        size = int(np.prod(l.shape[1:])) if l.ndim > 1 else 1
        outs.append(red[off:off + size].reshape(l.shape[1:]).astype(l.dtype))
        off += size
    return jax.tree.unflatten(treedef, outs)


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AggStats:
    """Engine counters: `n_traces` counts XLA retraces (a steady-state
    round must hit the jit cache, i.e. n_traces stays flat while n_calls
    grows).  Byte volume is tracked on two axes that diverge once updates
    arrive compressed: ``wire_bytes`` is what actually crossed the
    transport (the compressed frame), ``folded_bytes`` the dense fp32
    equivalent the reduce is worth (for GB/s accounting).  For dense
    updates the two are equal."""

    n_calls: int = 0
    n_traces: int = 0
    last_wire_bytes: int = 0
    total_wire_bytes: int = 0
    last_folded_bytes: int = 0
    total_folded_bytes: int = 0

    def record(self, folded: int, wire: Optional[int] = None) -> None:
        """Account one update: dense-equivalent bytes, and wire bytes if
        they differ (``wire=None`` means the update arrived dense)."""
        w = folded if wire is None else wire
        self.last_wire_bytes = w
        self.total_wire_bytes += w
        self.last_folded_bytes = folded
        self.total_folded_bytes += folded

    # Back-compat aliases: `last_bytes`/`total_bytes` always meant the
    # dense in-memory volume of the reduce, which is the folded axis.
    @property
    def last_bytes(self) -> int:
        return self.last_folded_bytes

    @property
    def total_bytes(self) -> int:
        return self.total_folded_bytes


class AggregationEngine:
    """Backend-aware fused FedAvg reducer with cached per-model plans.

    Parameters
    ----------
    backend : override ``jax.default_backend()`` ("tpu" enables the
        flatten-once + Pallas + donation path).
    use_pallas : force the kernel path on/off (defaults to backend=="tpu").
    interpret : explicit Pallas interpret-mode override (tests); None
        defers to backend detection in `kernels.ops`.
    chunk_elems : if set, `reduce_flat` streams in column blocks of this
        many elements (O(N·block) working memory).
    """

    def __init__(
        self,
        backend: Optional[str] = None,
        use_pallas: Optional[bool] = None,
        interpret: Optional[bool] = None,
        chunk_elems: Optional[int] = None,
    ) -> None:
        self.backend = backend if backend is not None else jax.default_backend()
        self.use_pallas = (self.backend == "tpu") if use_pallas is None else use_pallas
        self.interpret = interpret
        self.chunk_elems = chunk_elems
        self.stats = AggStats()
        self._tree_reduce_cache: Dict[Any, Callable] = {}

    # -- weights -------------------------------------------------------------
    @staticmethod
    def _normalized_weights(weights: Sequence[float]) -> np.ndarray:
        w = np.asarray(weights, np.float64)
        if w.ndim != 1 or w.size == 0:
            raise ValueError("weights must be a non-empty 1-D sequence")
        if w.sum() <= 0:
            raise ValueError("aggregation weights must sum to a positive value")
        return (w / w.sum()).astype(np.float32)

    # -- tree path (FLServer hot path) ---------------------------------------
    def aggregate(self, client_params: Sequence[Any], weights: Sequence[float]) -> Any:
        """Weighted average of N client pytrees in one fused call.

        Numerically equivalent to the `aggregation.fedavg` oracle (fp32
        accumulation, cast back to each leaf's dtype) but with exactly
        one pass over the client bytes per round.
        """
        w = self._normalized_weights(weights)
        if len(client_params) != w.size:
            raise ValueError("len(client_params) != len(weights)")
        self.stats.n_calls += 1
        nbytes = sum(l.nbytes for t in client_params for l in jax.tree.leaves(t))
        self.stats.record(nbytes)

        if self.use_pallas:
            plan = plan_for(client_params[0])
            stacked = plan.flatten_stack(list(client_params))
            red = self.reduce_flat(stacked, jnp.asarray(w))
            return plan.unflatten(red)

        fn = self._get_tree_reduce(client_params)
        return fn(list(client_params), jnp.asarray(w))

    def _get_tree_reduce(self, client_params: Sequence[Any]) -> Callable:
        key = (len(client_params), _structure_key(client_params[0]))
        fn = self._tree_reduce_cache.get(key)
        if fn is not None:
            return fn
        stats = self.stats

        def tree_reduce(trees, w):
            stats.n_traces += 1  # executes at trace time only

            def avg(*leaves):
                acc = leaves[0].astype(jnp.float32) * w[0]
                for i in range(1, len(leaves)):
                    acc = acc + leaves[i].astype(jnp.float32) * w[i]
                return acc.astype(leaves[0].dtype)

            return jax.tree.map(avg, *trees)

        fn = jax.jit(tree_reduce)
        self._tree_reduce_cache[key] = fn
        return fn

    # -- flat path ((N, L) stacked buffers) ----------------------------------
    def reduce_flat(
        self,
        stacked: jnp.ndarray,
        weights: jnp.ndarray,
        donate: Optional[bool] = None,
        chunk_elems: Optional[int] = None,
    ) -> jnp.ndarray:
        """Weighted average over axis 0 of a contiguous (N, L) buffer.

        ``donate=True`` hands the stacked buffer to XLA (the caller must
        not reuse it); defaults to donating only on the Pallas/TPU path,
        where the buffer would otherwise be duplicated for padding.
        Chunked mode slices the buffer, so donation does not apply there
        (an explicit ``donate=True`` with chunking is an error).
        """
        if stacked.ndim != 2:
            raise ValueError(f"expected (N, L) stacked buffer, got {stacked.shape}")
        w = weights.astype(jnp.float32)
        chunk = chunk_elems if chunk_elems is not None else self.chunk_elems
        if chunk:
            if donate:
                raise ValueError("chunked reduce slices the buffer; donation "
                                 "does not apply (pass donate=False/None)")
            return self._reduce_flat_chunked(stacked, w, int(chunk))
        if donate is None:
            donate = self.use_pallas and self.backend == "tpu"
        return self._get_flat_reduce(donate)(stacked, w)

    def _get_flat_reduce(self, donate: bool) -> Callable:
        """Per-engine jitted flat reduce (trace-counted, backend-routed)."""
        key = ("flat", self.use_pallas, bool(donate))
        fn = self._tree_reduce_cache.get(key)
        if fn is not None:
            return fn
        stats = self.stats
        if self.use_pallas:
            interp = self.interpret
            if interp is None:
                from repro.kernels.ops import _interpret_default
                interp = _interpret_default()

            def flat_reduce(stacked, w):
                stats.n_traces += 1  # executes at trace time only
                return _pallas_flat_reduce(stacked, w, interpret=interp)
        else:
            def flat_reduce(stacked, w):
                stats.n_traces += 1  # executes at trace time only
                return _dot_reduce(stacked, w / jnp.sum(w))

        fn = jax.jit(flat_reduce, donate_argnums=(0,) if donate else ())
        self._tree_reduce_cache[key] = fn
        return fn

    def _reduce_flat_chunked(self, stacked, w, chunk):
        """Column-blocked streaming reduce: O(N*chunk) working set.

        Each block goes through the same backend-routed reduce as the
        unchunked path (Pallas kernel when use_pallas, einsum otherwise)."""
        _, L = stacked.shape
        fn = self._get_flat_reduce(donate=False)
        outs = [fn(stacked[:, off:off + chunk], w) for off in range(0, L, chunk)]
        return jnp.concatenate(outs) if len(outs) > 1 else outs[0]

    # -- streaming -----------------------------------------------------------
    def streaming(self, base: Any = None) -> "StreamingAggregator":
        """New per-round streaming accumulator (async client folding).

        ``base`` switches the aggregator to flat/delta mode anchored on
        the round's global weights — required to fold
        :class:`~repro.federated.compression.CompressedUpdate` payloads
        (deltas against ``base``) and numerically identical to the plain
        weighted average for dense updates (the base cancels exactly)."""
        return StreamingAggregator(self, base=base)


# ---------------------------------------------------------------------------
# Streaming / incremental accumulation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CarryEntry:
    """One late ``c_msg_train`` buffered for a later round's average.

    The update was computed against ``origin_round``'s global weights; when
    it is finally folded, its example weight is discounted by the staleness
    factor ``discount ** (fold_round - origin_round)`` so fresh silos
    dominate while the straggler's contribution still lands (never silently
    dropped)."""

    client_id: str
    params: Any
    weight: float       # raw example weight (n_samples), undiscounted
    origin_round: int   # round whose deadline the message missed
    late_by_s: float = 0.0  # virtual seconds past that round's deadline

    def age_at(self, round_idx: int) -> int:
        """Rounds of staleness when folded in ``round_idx`` (floor 1).

        The single source of the age rule — `fold_carry` and the async
        round engine's timed drain both discount by ``discount**age_at``."""
        return max(1, round_idx - self.origin_round)


class CarryOverBuffer:
    """Late updates parked between rounds (deadline-driven partial rounds).

    The async round engine defers any ``c_msg_train`` that misses its
    round's ``T_round`` deadline into this buffer; the next round's
    :class:`StreamingAggregator` drains it first (the messages are already
    on the server), folding each entry with a staleness-discounted weight.
    """

    def __init__(self) -> None:
        self._entries: List[CarryEntry] = []

    def defer(self, entry: CarryEntry) -> None:
        self._entries.append(entry)

    def drain(self) -> List[CarryEntry]:
        entries, self._entries = self._entries, []
        return entries

    def clients(self) -> List[str]:
        return [e.client_id for e in self._entries]

    def pending_weight(self) -> float:
        """Total raw (undiscounted) example weight awaiting a fold."""
        return sum(e.weight for e in self._entries)

    def __len__(self) -> int:
        return len(self._entries)

    def __bool__(self) -> bool:
        return bool(self._entries)


@jax.jit
def _scale_tree(tree, w):
    return jax.tree.map(lambda l: l.astype(jnp.float32) * w, tree)


# The accumulator is donated: same shape/dtype in and out, so XLA updates
# it in place — O(L) extra memory total, regardless of client count.
@functools.partial(jax.jit, donate_argnums=(0,))
def _accum_tree(acc, tree, w):
    return jax.tree.map(lambda a, l: a + l.astype(jnp.float32) * w, acc, tree)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scale_acc(acc, inv):
    return jax.tree.map(lambda a: a * inv, acc)


# Flat-mode (delta) folds: the padded fp32 accumulator is donated so XLA
# updates it in place, exactly like the tree-mode `_accum_tree`.
@functools.partial(jax.jit, donate_argnums=(0,))
def _flat_delta_fold(acc, flat, base, w):
    """acc[:L] += (flat - base) * w — dense update folded as a delta."""
    return acc.at[: base.shape[0]].add((flat - base) * w)


@functools.partial(jax.jit, donate_argnums=(0,))
def _flat_scatter_fold(acc, idx, vals, w):
    """acc[idx] += vals * w — the top-k sparse fold (fp16 values)."""
    return acc.at[idx].add(vals.astype(jnp.float32) * w)


@functools.partial(jax.jit, donate_argnums=(0,))
def _flat_dequant_fold_jnp(acc, data, scales, w):
    """Fused dequantize-and-fold for einsum-tier backends: one jitted
    pass, same per-block math as the Pallas `dequant_fold` kernel."""
    nb = scales.shape[0]
    x = data.reshape(nb, -1).astype(jnp.float32)
    return acc + ((w * scales)[:, None] * x).reshape(acc.shape)


@jax.jit
def _flat_finalize(acc, base, inv):
    """base + acc[:L] * inv — the flat-mode weighted average.  The padded
    accumulator is NOT donated here: the (L,) output can't alias it."""
    return base + acc[: base.shape[0]] * inv


def _leaf_nbytes(leaf: Any) -> int:
    nbytes = getattr(leaf, "nbytes", None)
    return int(nbytes) if nbytes is not None else int(np.asarray(leaf).nbytes)


class StreamingAggregator:
    """Running weighted accumulation: fold clients in as they land.

    ``add(params, weight)`` costs one fused pass over that client's
    bytes and keeps only a single fp32 accumulator (donated in place),
    so asynchronously arriving silos are aggregated in O(L) memory
    rather than O(N·L).  ``result()`` normalizes by the running weight
    total, casts back to the model dtypes, consumes the accumulator, and
    resets all per-fold state so a reused aggregator starts a fresh fold.

    With ``base`` (the round's global weights) the aggregator runs in
    *flat/delta mode*: one padded fp32 vector accumulator, every update
    folded as ``w * (update - base)`` and the result read out as
    ``base + acc / wsum`` — numerically the same weighted average (the
    base cancels exactly), but able to fold
    :class:`~repro.federated.compression.CompressedUpdate` payloads
    (int8 / fp16 / top-k deltas) directly via the fused Pallas
    dequantize-and-fold kernel, never materializing a dense fp32 update.
    """

    def __init__(
        self, engine: Optional[AggregationEngine] = None, base: Any = None
    ) -> None:
        self._engine = engine
        self._plan: Optional[RavelPlan] = None
        self._base_flat: Optional[jnp.ndarray] = None
        self._padded_len = 0
        if base is not None:
            from repro.kernels.fedavg_reduce import BLOCK as _block
            self._plan = plan_for(base)
            self._base_flat = self._plan.flatten(base)
            self._padded_len = -(-self._plan.total_elems // _block) * _block
        self._acc: Any = None
        self._acc_flat: Optional[jnp.ndarray] = None
        self._dtypes: Optional[List[Any]] = None
        self._treedef = None
        self._wsum = 0.0
        self.n_clients = 0

    def _reset(self) -> None:
        """Clear per-fold state (`result()` calls this); the base/plan
        are construction-time configuration and survive for reuse."""
        self._acc = None
        self._acc_flat = None
        self._dtypes = None
        self._treedef = None
        self._wsum = 0.0
        self.n_clients = 0

    def _ensure_flat_acc(self) -> jnp.ndarray:
        if self._acc_flat is None:
            self._acc_flat = jnp.zeros(self._padded_len, jnp.float32)
        return self._acc_flat

    def add(
        self,
        params: Any,
        weight: float,
        block: bool = False,
        wire_bytes: Optional[int] = None,
    ) -> None:
        """Fold one client in; ``block=True`` waits for the fused
        accumulate to finish (the async round engine uses it to measure
        the true per-fold cost instead of dispatch latency).
        ``wire_bytes`` is the transport frame size when it differs from
        the dense in-memory bytes (compressed arrivals); compressed
        payloads themselves route to :meth:`add_compressed`."""
        from repro.federated.compression import CompressedUpdate
        if isinstance(params, CompressedUpdate):
            self.add_compressed(params, weight, block=block, wire_bytes=wire_bytes)
            return
        w = float(weight)
        if w < 0:
            raise ValueError("client weight must be non-negative")
        if self._base_flat is not None:
            flat = self._plan.flatten(params)
            if flat.shape[0] != self._base_flat.shape[0]:
                raise ValueError(
                    f"update has {flat.shape[0]} elements; the aggregation "
                    f"base has {self._base_flat.shape[0]}"
                )
            acc = self._ensure_flat_acc()
            self._acc_flat = _flat_delta_fold(
                acc, flat, self._base_flat, jnp.float32(w)
            )
            folded = self._acc_flat
        elif self._acc is None:
            leaves, self._treedef = jax.tree.flatten(params)
            # Pin accumulator dtypes from the first client's *concrete*
            # leaf dtypes (what jnp.asarray actually stores) — never
            # jnp.result_type, which weak-type-promotes Python-scalar
            # and numpy-default leaves past what jax will materialize.
            self._dtypes = [jnp.asarray(l).dtype for l in leaves]
            self._acc = _scale_tree(params, jnp.float32(w))
            folded = self._acc
        else:
            self._acc = _accum_tree(self._acc, params, jnp.float32(w))
            folded = self._acc
        if block:
            jax.block_until_ready(folded)
        self._wsum += w
        self.n_clients += 1
        if self._engine is not None:
            nbytes = sum(_leaf_nbytes(l) for l in jax.tree.leaves(params))
            self._engine.stats.record(nbytes, wire_bytes)

    def add_compressed(
        self,
        update: Any,
        weight: float,
        block: bool = False,
        wire_bytes: Optional[int] = None,
    ) -> None:
        """Fold one compressed delta straight into the fp32 accumulator.

        int8 / fp16 payloads go through the fused Pallas
        ``dequant_fold`` kernel (or its jitted fallback on einsum-tier
        backends) — one pass over the quantized bytes, no dense fp32
        intermediate; top-k payloads fold with a donated sparse scatter.
        """
        if self._base_flat is None or self._plan is None:
            raise ValueError(
                "compressed updates need a delta base: construct the "
                "aggregator with streaming(base=global_params)"
            )
        if update.total_elems != self._plan.total_elems:
            raise ValueError(
                f"compressed update has {update.total_elems} elements; "
                f"the model has {self._plan.total_elems}"
            )
        w = float(weight)
        if w < 0:
            raise ValueError("client weight must be non-negative")
        acc = self._ensure_flat_acc()
        lp = self._padded_len
        if update.codec == "topk":
            self._acc_flat = _flat_scatter_fold(
                acc,
                jnp.asarray(np.asarray(update.indices)),
                jnp.asarray(np.asarray(update.data)),
                jnp.float32(w),
            )
        elif update.codec in ("int8", "fp16"):
            from repro.federated.compression import QBLOCK
            nb = lp // QBLOCK
            data = np.zeros(lp, dtype=update.data.dtype)
            data[: update.total_elems] = update.data
            if update.codec == "int8":
                scales = np.asarray(update.scales, np.float32)
                if scales.shape != (nb,):
                    raise ValueError(
                        f"int8 update has {scales.shape} scales; expected ({nb},)"
                    )
            else:
                scales = np.ones(nb, np.float32)
            if self._use_pallas():
                from repro.kernels.fedavg_reduce import dequant_fold
                interp = self._engine.interpret if self._engine is not None else None
                self._acc_flat = dequant_fold(
                    acc, jnp.asarray(data), jnp.asarray(scales),
                    jnp.float32(w), interpret=interp,
                )
            else:
                self._acc_flat = _flat_dequant_fold_jnp(
                    acc, jnp.asarray(data), jnp.asarray(scales), jnp.float32(w)
                )
        else:
            raise ValueError(f"unknown compressed codec {update.codec!r}")
        if block:
            jax.block_until_ready(self._acc_flat)
        self._wsum += w
        self.n_clients += 1
        if self._engine is not None:
            wire = wire_bytes if wire_bytes is not None else update.wire_bytes
            self._engine.stats.record(update.dense_bytes, wire)

    def _use_pallas(self) -> bool:
        if self._engine is not None:
            return bool(self._engine.use_pallas)
        return jax.default_backend() == "tpu"

    def add_stale(
        self,
        params: Any,
        weight: float,
        stale_rounds: int,
        discount: float,
        block: bool = False,
    ) -> float:
        """Fold a carried-over (stale) update with a staleness-discounted
        weight ``weight * discount**stale_rounds``; returns the effective
        weight that entered the average."""
        if stale_rounds < 1:
            raise ValueError("a stale fold must be at least one round late")
        if not 0.0 <= discount <= 1.0:
            raise ValueError("staleness discount must be in [0, 1]")
        w_eff = float(weight) * float(discount) ** int(stale_rounds)
        self.add(params, w_eff, block=block)
        return w_eff

    def fold_carry(
        self,
        buffer: CarryOverBuffer,
        round_idx: int,
        discount: float,
        block: bool = False,
    ) -> List[Tuple[CarryEntry, float]]:
        """Drain a :class:`CarryOverBuffer` into the accumulator.

        Every parked entry is folded with its staleness discount applied
        (age = ``round_idx - origin_round`` rounds, at least 1); returns
        the ``(entry, effective_weight)`` pairs so callers can account the
        raw-vs-discounted weights (weight conservation audits)."""
        folded: List[Tuple[CarryEntry, float]] = []
        for entry in buffer.drain():
            w_eff = self.add_stale(
                entry.params, entry.weight, entry.age_at(round_idx),
                discount, block=block,
            )
            folded.append((entry, w_eff))
        return folded

    def result(self) -> Any:
        if self._acc is None and self._acc_flat is None:
            raise ValueError("no clients have been added")
        if self._wsum <= 0:
            raise ValueError("aggregation weights must sum to a positive value")
        if self._acc_flat is not None:
            assert self._plan is not None and self._base_flat is not None
            vec = _flat_finalize(
                self._acc_flat, self._base_flat, jnp.float32(1.0 / self._wsum)
            )
            out = self._plan.unflatten(vec)
        else:
            acc = _scale_acc(self._acc, jnp.float32(1.0 / self._wsum))
            leaves = jax.tree.leaves(acc)
            outs = [l.astype(dt) for l, dt in zip(leaves, self._dtypes)]
            out = jax.tree.unflatten(self._treedef, outs)
        # Consume: the accumulator was donated, and every per-fold field
        # (_wsum, n_clients, _dtypes, _treedef) must go with it — stale
        # normalizer state would silently double-count on reuse.
        self._reset()
        if self._engine is not None:
            self._engine.stats.n_calls += 1
        return out


# ---------------------------------------------------------------------------
# Cost-accounting hook (simulator integration)
# ---------------------------------------------------------------------------

def make_measured_aggreg_fn(
    env: Any,
    bytes_per_round: int,
    gb_per_s: float,
    base_vm_id: Optional[str] = None,
) -> Callable[[str], float]:
    """Build a `CostModel.t_aggreg` override from a measured reduce rate.

    ``bytes_per_round`` is the dense-equivalent byte volume the server
    reduces each round (N clients x model bytes, e.g.
    `AggStats.last_folded_bytes` — the reduce runs over dequantized fp32
    regardless of what crossed the wire, so folded, not wire, bytes set
    the aggregation time);
    ``gb_per_s`` the measured engine bandwidth (benchmarks/aggregation_bench
    reports it per shape).  The time scales with each VM's instance
    slowdown exactly like the paper's `aggreg_bl` baseline does.
    """
    if gb_per_s <= 0:
        raise ValueError("gb_per_s must be positive")
    base_s = bytes_per_round / (gb_per_s * 1e9)
    base_slow = env.inst_slowdown(base_vm_id) if base_vm_id is not None else 1.0

    def t_aggreg(vm_id: str) -> float:
        return base_s * env.inst_slowdown(vm_id) / base_slow

    return t_aggreg
