"""Deterministic chaos engineering for the Multi-FedLS fault-tolerance story.

The paper's viability claim (§4.3 checkpoint + re-request recovery, §4.4
replacement-VM selection) is only as strong as the faults it has been
exercised against.  This module turns fault injection from hand-scripted
test scenarios into a *seeded, replayable plan*: a :class:`FaultPlan` is
a declarative set of :class:`FaultSpec` records — each targeting one
silo, one round, one phase — that the **same plan object** executes on
both control-plane drivers:

* virtual clock — :class:`ChaosSchedule` decorates any
  :class:`~repro.federated.async_server.ArrivalSchedule` (the
  :class:`~repro.federated.async_server.RevocationInjector` idiom) and
  rewrites the round's :class:`~repro.federated.async_server.
  ClientArrival` records: crash/hang/disconnect/revocation become a
  ``revoke_at_s`` before delivery, ``slow`` adds reply delay,
  ``corrupt_frame`` revokes exactly *at* delivery (the update arrived
  but is unusable — the §4.3 re-request boundary).
* wall clock — :class:`ChaosClient` wraps a real ``FLClient`` behind
  the socket transport and executes the client-side kinds physically
  (raise, block-and-stop-heartbeats, sleep, mangle the reply bytes),
  while :class:`~repro.federated.transport.LiveRoundDriver` executes
  the driver-side kinds (force-sever a connection, corrupt the newest
  checkpoint file) when constructed with ``chaos=plan``.

Every injected fault is published as a typed
:class:`~repro.core.events.FaultInjected` event at the point of
injection, so the trace shows cause and §4.3/§4.4 effect side by side;
:func:`verify_fault_pairing` checks the soak invariant that every
injected fault is paired with a recovery or exclusion event, and
:func:`chaos_signature` gives the cross-driver parity view (within-round
event multisets modulo timestamps — measured arrival *order* under real
faults is scheduler noise; the strict ordered parity on fault-free and
single-fault scenarios stays pinned by ``tests/test_transport.py``).

Fault kinds (:data:`FAULT_KINDS`):

====================  ======================================================
``crash``             the silo's ``train``/``evaluate`` raises (connection
                      drops: the §4.3 hard-fault signal)
``hang``              the silo blocks *and stops answering heartbeats* —
                      distinguishable from ``slow`` only by liveness
                      detection (the driver's heartbeat timeout)
``slow``              the reply is delayed by ``delay_s`` seconds (§4.4
                      straggler evidence; heartbeats keep flowing)
``disconnect``        the server-side connection is severed mid-round
``corrupt_frame``     the reply arrives but its payload is mangled — the
                      driver must treat an undecodable ``c_msg_train``
                      as a suspected fault and re-request
``corrupt_checkpoint``  the newest checkpoint file is bit-flipped /
                      truncated on disk; the §4.3 restore must fall back
                      to the newest *verified* checkpoint
``revocation``        the silo's VM is revoked; the restart may land on a
                      *different* host chosen by
                      ``DynamicScheduler.select_instance`` (§4.4 —
                      published as ``VMReplaced`` on the live driver)
====================  ======================================================
"""
from __future__ import annotations

import dataclasses
import os
import random
from typing import (
    Any,
    Callable,
    Dict,
    Iterable,
    List,
    Optional,
    Sequence,
    Set,
    Tuple,
)

from repro.core.events import (
    EventBus,
    FaultInjected,
    RecoveryCompleted,
    RevocationOccurred,
    RoundClosed,
    UpdateArrived,
    UpdateFolded,
)
from .async_server import ArrivalSchedule, ClientArrival

__all__ = [
    "FAULT_KINDS",
    "ChaosClient",
    "ChaosSchedule",
    "FaultPlan",
    "FaultSpec",
    "chaos_signature",
    "checkpoint_saboteur",
    "corrupt_latest_checkpoint",
    "verify_fault_pairing",
]

FAULT_KINDS: Tuple[str, ...] = (
    "crash",
    "hang",
    "slow",
    "disconnect",
    "corrupt_frame",
    "corrupt_checkpoint",
    "revocation",
)
_PHASES: Tuple[str, ...] = ("train", "eval")

# Who executes each kind. Client kinds run inside the worker
# (ChaosClient); driver kinds are transport/filesystem actions taken by
# LiveRoundDriver.  On the virtual clock every non-checkpoint kind maps
# onto the arrival model (ChaosSchedule).
CLIENT_KINDS: Tuple[str, ...] = ("crash", "hang", "slow", "corrupt_frame")
DRIVER_KINDS: Tuple[str, ...] = ("disconnect", "revocation")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One planned fault: ``kind`` hits ``task`` in ``round_idx``/``phase``.

    ``delay_s`` is the extra reply latency of a ``slow`` fault (and the
    block duration bound of a ``hang``); ``at_s`` is the virtual-clock
    injection offset used by :class:`ChaosSchedule` (clamped to the
    victim's delivery time so the fault actually interrupts).
    """

    kind: str
    task: str
    round_idx: int
    phase: str = "train"
    delay_s: float = 0.0
    at_s: float = 0.0

    def __post_init__(self) -> None:
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}: one of {FAULT_KINDS}"
            )
        if self.phase not in _PHASES:
            raise ValueError(f"phase must be one of {_PHASES}")
        if self.round_idx < 1:
            raise ValueError("round_idx is 1-indexed: must be >= 1")
        if self.delay_s < 0.0 or self.at_s < 0.0:
            raise ValueError("delay_s and at_s must be >= 0")

    @property
    def key(self) -> Tuple[str, str, int, str]:
        return (self.kind, self.task, self.round_idx, self.phase)


class FaultPlan:
    """A deterministic, seeded set of faults — one plan, every driver.

    Faults are kept in a canonical order (round, phase, task, kind) so
    injection order — and therefore the published ``FaultInjected``
    sequence — is identical on every driver and every replay.
    """

    def __init__(self, faults: Iterable[FaultSpec], seed: int = 0) -> None:
        ordered = sorted(
            faults, key=lambda f: (f.round_idx, f.phase, f.task, f.kind)
        )
        seen: Set[Tuple[str, str, int, str]] = set()
        for f in ordered:
            if f.key in seen:
                raise ValueError(f"duplicate fault {f.key}")
            seen.add(f.key)
        self.faults: Tuple[FaultSpec, ...] = tuple(ordered)
        self.seed = int(seed)

    def __iter__(self) -> Any:
        return iter(self.faults)

    def __len__(self) -> int:
        return len(self.faults)

    def __eq__(self, other: object) -> bool:
        return (
            isinstance(other, FaultPlan)
            and self.faults == other.faults
            and self.seed == other.seed
        )

    def __repr__(self) -> str:
        return f"FaultPlan(seed={self.seed}, faults={list(self.faults)!r})"

    @property
    def kinds(self) -> Set[str]:
        return {f.kind for f in self.faults}

    @property
    def max_round(self) -> int:
        return max((f.round_idx for f in self.faults), default=0)

    def faults_for(
        self,
        round_idx: int,
        phase: Optional[str] = None,
        task: Optional[str] = None,
    ) -> Tuple[FaultSpec, ...]:
        return tuple(
            f
            for f in self.faults
            if f.round_idx == round_idx
            and (phase is None or f.phase == phase)
            and (task is None or f.task == task)
        )

    def wrap_clients(self, clients: Sequence[Any]) -> List["ChaosClient"]:
        """Wrap live ``FLClient`` objects so the plan's client-side kinds
        execute physically inside their workers."""
        return [ChaosClient(c, self) for c in clients]

    @classmethod
    def seeded(
        cls,
        seed: int,
        *,
        n_rounds: int,
        tasks: Sequence[str],
        kinds: Sequence[str] = CLIENT_KINDS + DRIVER_KINDS,
        n_faults: int = 4,
        slow_delay_s: float = 0.25,
    ) -> "FaultPlan":
        """Draw a deterministic multi-fault plan from a seed.

        Same ``(seed, n_rounds, tasks, kinds, n_faults)`` always yields
        the same plan — the replayability contract chaos soaks rely on.
        """
        if not tasks:
            raise ValueError("tasks must be non-empty")
        for k in kinds:
            if k not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {k!r}")
        rng = random.Random(int(seed))
        universe = [
            (r, t, k)
            for r in range(1, n_rounds + 1)
            for t in tasks
            for k in kinds
        ]
        if n_faults > len(universe):
            raise ValueError(
                f"n_faults={n_faults} exceeds the {len(universe)} distinct "
                "(round, task, kind) combinations"
            )
        picks = rng.sample(universe, n_faults)
        faults = [
            FaultSpec(
                kind=k,
                task=t,
                round_idx=r,
                delay_s=slow_delay_s if k in ("slow", "hang") else 0.0,
            )
            for r, t, k in picks
        ]
        return cls(faults, seed=seed)


# ---------------------------------------------------------------------------
# Virtual-clock execution: the arrival-model view of a plan
# ---------------------------------------------------------------------------

class ChaosSchedule(ArrivalSchedule):
    """Execute a :class:`FaultPlan` on the virtual-clock arrival model.

    Decorates any inner schedule (like ``RevocationInjector``) and, per
    round, publishes one ``FaultInjected`` marker per planned fault (in
    plan order — matching where the live driver publishes its markers)
    and rewrites the train-phase arrivals:

    * ``crash`` / ``hang`` / ``disconnect`` / ``revocation`` — revoked at
      ``min(at_s, delay_s)``: the update is lost before delivery and the
      engine's §4.3 re-request-or-exclude machinery takes over.  (The
      virtual clock cannot distinguish these kinds — they differ only in
      *how* the live transport observes them.)
    * ``slow`` — ``delay_s`` is added to the reply latency.
    * ``corrupt_frame`` — revoked exactly **at** delivery: the message
      arrived but is unusable, so recovery costs a full re-request.

    Eval-phase and ``corrupt_checkpoint`` faults don't touch arrivals
    (eval is metrics-only on the virtual clock; checkpoint sabotage is
    :func:`checkpoint_saboteur`'s job) — eval-phase markers are still
    published so traces stay comparable across drivers.
    """

    def __init__(
        self,
        inner: ArrivalSchedule,
        plan: FaultPlan,
        bus: Optional[EventBus] = None,
    ) -> None:
        self.inner = inner
        self.plan = plan
        self.bus = bus

    def round_arrivals(
        self, round_idx: int, client_ids: Sequence[str]
    ) -> Dict[str, ClientArrival]:
        arrivals = dict(self.inner.round_arrivals(round_idx, client_ids))
        for f in self.plan.faults_for(round_idx):
            if f.kind == "corrupt_checkpoint":
                continue  # marker comes from checkpoint_saboteur
            if self.bus is not None:
                self.bus.publish(
                    FaultInjected(f.at_s, f.kind, f.task, round_idx, f.phase)
                )
            if f.phase != "train" or f.task not in arrivals:
                continue
            a = arrivals[f.task]
            if f.kind == "slow":
                arrivals[f.task] = dataclasses.replace(
                    a, delay_s=a.delay_s + f.delay_s
                )
            elif f.kind == "corrupt_frame":
                arrivals[f.task] = dataclasses.replace(
                    a, revoke_at_s=a.delay_s
                )
            else:  # crash | hang | disconnect | revocation
                arrivals[f.task] = dataclasses.replace(
                    a, revoke_at_s=min(f.at_s, a.delay_s)
                )
        return arrivals


# ---------------------------------------------------------------------------
# Wall-clock execution: the worker-side view of a plan
# ---------------------------------------------------------------------------

class ChaosFault(RuntimeError):
    """Raised by :class:`ChaosClient` to execute a ``crash`` fault."""


class ChaosClient:
    """Duck-typed ``FLClient`` wrapper executing client-side fault kinds.

    The socket worker loop (:func:`~repro.federated.transport.
    run_client_worker`) recognizes three optional hooks, all provided
    here: ``on_round(round_idx, phase)`` arms the wrapper before each
    compute, ``heartbeat_ok()`` gates ping replies (False while a hang
    fault is active, so the driver's liveness detector can tell a hang
    from a merely slow silo), and ``mangle_payload(body)`` corrupts the
    serialized reply bytes for a ``corrupt_frame`` fault.

    Each fault fires **once** per (kind, task, round, phase) — a §4.3
    re-request after the fault therefore succeeds, exactly like a
    replacement VM rejoining.  The same wrapper object survives worker
    restarts (thread pools respawn over the same client), which is what
    carries the fired-set across attempts.
    """

    def __init__(self, inner: Any, plan: FaultPlan, hang_s: float = 30.0) -> None:
        import threading

        self.inner = inner
        self.plan = plan
        self.hang_s = hang_s
        self._fired: Set[Tuple[str, str, int, str]] = set()
        self._round = 0
        self._phase = "train"
        self._hung = threading.Event()
        self._released = threading.Event()
        self._lock = threading.Lock()

    @property
    def client_id(self) -> Any:
        return self.inner.client_id

    # -- worker hooks ------------------------------------------------------
    def on_round(self, round_idx: int, phase: str) -> None:
        with self._lock:
            self._round = int(round_idx)
            self._phase = phase
            # A restarted worker thread must answer heartbeats again:
            # the hang that killed its predecessor has already fired.
            self._hung.clear()

    def heartbeat_ok(self) -> bool:
        return not self._hung.is_set()

    def release(self) -> None:
        """Wake any thread stuck in a hang fault (pool shutdown calls
        this so orphaned compute threads don't outlive the driver)."""
        self._released.set()

    def mangle_payload(self, body: bytes) -> bytes:
        f = self._take("corrupt_frame")
        if f is None:
            return body
        # Truncate to half: undecodable by any framing, deterministic.
        return body[: max(1, len(body) // 2)]

    # -- FLClient surface --------------------------------------------------
    def train(self, global_params: Any) -> Any:
        self._apply()
        return self.inner.train(global_params)

    def evaluate(self, aggregated_params: Any) -> Any:
        self._apply()
        return self.inner.evaluate(aggregated_params)

    # -- internals ---------------------------------------------------------
    def _take(self, *kinds: str) -> Optional[FaultSpec]:
        with self._lock:
            for f in self.plan.faults_for(self._round, self._phase,
                                          str(self.client_id)):
                if f.kind in kinds and f.key not in self._fired:
                    self._fired.add(f.key)
                    return f
        return None

    def _apply(self) -> None:
        import time

        f = self._take("crash", "hang", "slow")
        if f is None:
            return
        if f.kind == "crash":
            raise ChaosFault(
                f"injected crash: {self.client_id} round {f.round_idx}"
            )
        if f.kind == "hang":
            # Block silently and stop answering heartbeats.  The bound
            # (or a pool-shutdown release()) exists only so the orphaned
            # thread eventually dies; the driver's heartbeat timeout is
            # what actually notices.
            self._hung.set()
            self._released.wait(max(self.hang_s, f.delay_s))
            raise ChaosFault(
                f"injected hang expired: {self.client_id} round {f.round_idx}"
            )
        # slow: delay the reply, heartbeats keep flowing.
        time.sleep(f.delay_s)


# ---------------------------------------------------------------------------
# Checkpoint sabotage (corrupt_checkpoint, both drivers)
# ---------------------------------------------------------------------------

def corrupt_latest_checkpoint(server_ckpt: Any) -> List[str]:
    """Truncate the newest checkpoint file on *every* replica.

    Hits the same ``round_N.ckpt`` in both the local and the remote
    (durable) directory — corrupting only one replica would let restore
    trivially read the twin; hitting both is what forces the §4.3
    fallback to the newest *verified* (older or client-side) checkpoint.
    Returns the corrupted paths (empty when nothing is saved yet).
    """
    from repro.checkpoint.manager import _list_ckpts

    dirs = [
        d
        for d in (
            getattr(server_ckpt, "remote_dir", None),
            getattr(server_ckpt, "local_dir", None),
        )
        if d
    ]
    newest: Optional[str] = None
    newest_round = -1
    for d in dirs:
        for ck in _list_ckpts(d):
            if ck.round_idx > newest_round:
                newest_round = ck.round_idx
                newest = os.path.basename(ck.path)
    if newest is None:
        return []
    corrupted: List[str] = []
    for d in dirs:
        path = os.path.join(d, newest)
        if not os.path.exists(path):
            continue
        with open(path, "rb") as f:
            blob = f.read()
        with open(path, "wb") as f:
            f.write(blob[: len(blob) // 2])
        corrupted.append(path)
    return corrupted


def checkpoint_saboteur(
    plan: FaultPlan,
    server_ckpt: Any,
    bus: EventBus,
) -> Callable[[int], Optional[str]]:
    """Build an ``FLServer``-compatible ``fault_hook`` executing the
    plan's ``corrupt_checkpoint`` faults on the virtual-clock driver.

    At each planned round the hook publishes the ``FaultInjected``
    marker, corrupts the newest checkpoint on disk, and returns ``"s"``
    so the server runs its §4.3 restore — which must fall back past the
    corruption to the newest verified source (``RecoveryCompleted`` in
    the trace records where it actually restored from).
    """
    fired: Set[Tuple[str, str, int, str]] = set()

    def hook(round_idx: int) -> Optional[str]:
        victim: Optional[str] = None
        for f in plan.faults_for(round_idx):
            if f.kind != "corrupt_checkpoint" or f.key in fired:
                continue
            fired.add(f.key)
            bus.publish(
                FaultInjected(f.at_s, f.kind, f.task, round_idx, f.phase)
            )
            corrupt_latest_checkpoint(server_ckpt)
            victim = "s"
        return victim

    return hook


# ---------------------------------------------------------------------------
# Trace verification: pairing + cross-driver parity
# ---------------------------------------------------------------------------

def verify_fault_pairing(
    plan: FaultPlan, trace: Sequence[Any]
) -> Dict[Tuple[str, str, int, str], str]:
    """Map every planned fault to its recovery/exclusion evidence.

    Outcomes (the soak invariant is "no ``unpaired`` values"):

    * ``recovered`` — a same-round ``RevocationOccurred`` followed by an
      attempt>=2 ``UpdateArrived`` (§4.3 re-request landed);
    * ``excluded`` — ``RevocationOccurred`` with no recovery arrival
      (§4.3 budget exhausted / reply timeout);
    * ``delivered`` — the update still folded into its round (a ``slow``
      fault that stayed inside the horizon);
    * ``carried`` — parked by a deadline and folded stale (PR 3);
    * ``restored`` — a ``corrupt_checkpoint`` answered by a
      ``RecoveryCompleted`` for the server;
    * ``metrics-only`` — an eval-phase fault (costs this round's metrics
      only; cohort retention is driver state, not trace state);
    * ``unpaired`` — the marker or its recovery evidence is missing.
    """
    out: Dict[Tuple[str, str, int, str], str] = {}
    markers = {
        (e.kind, e.task, e.round_idx, e.phase)
        for e in trace
        if isinstance(e, FaultInjected)
    }
    for f in plan.faults:
        if f.key not in markers:
            out[f.key] = "unpaired"
            continue
        if f.kind == "corrupt_checkpoint":
            restored = any(
                isinstance(e, RecoveryCompleted)
                and e.task == "s"
                and e.resume_round == f.round_idx
                for e in trace
            )
            out[f.key] = "restored" if restored else "unpaired"
            continue
        if f.phase == "eval":
            out[f.key] = "metrics-only"
            continue
        revoked = any(
            isinstance(e, RevocationOccurred)
            and e.task == f.task
            and e.round_idx == f.round_idx
            for e in trace
        )
        recovered = any(
            isinstance(e, UpdateArrived)
            and e.task == f.task
            and e.round_idx == f.round_idx
            and e.attempt >= 2
            for e in trace
        )
        delivered = any(
            isinstance(e, UpdateFolded)
            and e.task == f.task
            and (e.round_idx == f.round_idx or e.origin_round == f.round_idx)
            for e in trace
        )
        carried = any(
            isinstance(e, RoundClosed)
            and e.round_idx == f.round_idx
            and f.task in e.carried_over
            for e in trace
        )
        if revoked and recovered:
            out[f.key] = "recovered"
        elif revoked:
            out[f.key] = "excluded"
        elif delivered:
            out[f.key] = "delivered"
        elif carried:
            out[f.key] = "carried"
        else:
            out[f.key] = "unpaired"
    return out


def chaos_signature(
    trace: Sequence[Any], exclude: Tuple[str, ...] = ("VMReplaced",)
) -> List[Tuple[Any, ...]]:
    """Cross-driver parity view of a chaotic trace.

    Events are reduced to ``(type, round, task, attempt, kind)`` tuples
    and sorted *within each round segment* (a segment ends at the
    round's ``RoundClosed``): under real multi-fault load, measured
    arrival order within a round is scheduler noise, but the per-round
    event multiset — who arrived, with what attempt number, what was
    revoked, folded, carried — must match the virtual-clock replay
    exactly.  ``VMReplaced`` is excluded by default: placement is
    live-driver state (the virtual driver has no host map).
    """
    sig: List[Tuple[Any, ...]] = []
    segment: List[Tuple[Any, ...]] = []
    for e in trace:
        name = type(e).__name__
        if name in exclude:
            continue
        entry = (
            name,
            getattr(e, "round_idx", None),
            getattr(e, "task", None),
            getattr(e, "attempt", None),
            getattr(e, "kind", None),
        )
        segment.append(entry)
        if name == "RoundClosed":
            sig.extend(sorted(segment, key=lambda t: tuple(map(repr, t))))
            segment = []
    sig.extend(sorted(segment, key=lambda t: tuple(map(repr, t))))
    return sig
