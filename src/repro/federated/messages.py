"""FL message accounting (paper §3).

Four message kinds per round: s_msg_train (server -> clients, initial
weights), c_msg_train (client -> server, updated weights), s_msg_aggreg
(server -> clients, aggregated weights), c_msg_test (client -> server, ML
metrics). Byte sizes are measured from the *actual serialized payloads*,
and feed the Eq.-6 communication-cost model.

With wire compression (:mod:`repro.federated.compression`) the
``c_msg_train`` leg carries a quantized/sparsified delta: the log's
``c_msg_train_bytes`` is then the *wire* size (what the cost model must
see — compressed frames are what cross the inter-cloud link), while
``c_msg_train_dense_bytes`` keeps the dense fp32 equivalent so reports
can state the achieved compression ratio.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, TYPE_CHECKING, Union

import msgpack

from repro.checkpoint.serializer import pytree_num_bytes, serialize_pytree
from repro.core.application_model import MessageSizes

if TYPE_CHECKING:
    from repro.federated.compression import CompressionSpec


@dataclasses.dataclass(frozen=True)
class RoundMessageLog:
    s_msg_train_bytes: int
    c_msg_train_bytes: int
    s_msg_aggreg_bytes: int
    c_msg_test_bytes: int
    # Wire-compression accounting: the codec the c_msg_train leg used
    # ("none" = raw fp32 pytree frames) and, when compressed, the dense
    # fp32 size the same update would have cost uncompressed.
    codec: str = "none"
    c_msg_train_dense_bytes: Optional[int] = None
    # Structured-update accounting: per-group wire and dense fp32 bytes
    # of the c_msg_train leg when clients ship named parameter groups
    # (None = unstructured round).  The sum of group_wire_bytes is the
    # structured frame's payload, so per-group ratios are first-class.
    group_wire_bytes: Optional[Dict[str, int]] = None
    group_dense_bytes: Optional[Dict[str, int]] = None

    def total_bytes(self, n_clients: int) -> int:
        """Bytes on the wire for a full round with n_clients."""
        return n_clients * (
            self.s_msg_train_bytes
            + self.c_msg_train_bytes
            + self.s_msg_aggreg_bytes
            + self.c_msg_test_bytes
        )

    @property
    def compression_ratio(self) -> Optional[float]:
        """dense / wire for the c_msg_train leg (None when uncompressed)."""
        if self.c_msg_train_dense_bytes is None or self.c_msg_train_bytes <= 0:
            return None
        return self.c_msg_train_dense_bytes / self.c_msg_train_bytes


def serialize_metrics(metrics: Dict[str, float]) -> bytes:
    """The wire form of a ``c_msg_test`` payload (msgpack, like weights)."""
    packed = msgpack.packb(
        {str(k): float(v) for k, v in metrics.items()}, use_bin_type=True
    )
    assert isinstance(packed, bytes)
    return packed


def measure_messages(
    params: Any,
    metrics_example: Dict[str, float],
    compression: Union[None, str, "CompressionSpec"] = None,
    schema: Any = None,
) -> RoundMessageLog:
    """Measure real serialized sizes for one round's message set.

    All four messages are measured from their actual serialized payloads
    — the metrics dict included, so Eq.-6 communication costs never mix
    measured weight transfers with a guessed per-key constant.  With
    ``compression`` the ``c_msg_train`` leg is the compressed frame size
    (exact: compressed frames are fixed-width given the element count),
    and the dense fp32 equivalent is reported alongside; the server->
    client legs always ship dense weights.

    With a ``schema`` (an :class:`~repro.federated.agg_engine.UpdateSchema`
    or a group mapping) the ``c_msg_train`` leg is a *structured* frame:
    only the named groups ride the wire, per-group byte maps fill
    ``group_wire_bytes``/``group_dense_bytes``, and the dense-equivalent
    stays the FULL model's fp32 size — the compression ratio then states
    what shipping groups instead of the whole pytree actually saved
    (e.g. the >= 50x of adapter-only federated LoRA)."""
    weight_bytes = len(serialize_pytree(params))
    metric_bytes = len(serialize_metrics(metrics_example))
    c_train_bytes = weight_bytes
    codec = "none"
    dense: Optional[int] = None
    group_wire: Optional[Dict[str, int]] = None
    group_dense: Optional[Dict[str, int]] = None
    if schema is not None:
        from repro.federated.agg_engine import plan_for
        from repro.federated.compression import (
            StructuredCompressor,
            serialize_structured,
        )

        comp = StructuredCompressor(schema, compression)
        update = comp.encode(params, params, base_round=0)
        c_train_bytes = len(serialize_structured(update))
        group_wire = update.group_wire_bytes()
        group_dense = update.group_dense_bytes()
        dense = plan_for(params).total_elems * 4
        codec = ("structured" if comp.spec is None
                 else f"structured:{comp.spec.codec}")
    elif compression is not None:
        from repro.federated.agg_engine import plan_for
        from repro.federated.compression import (
            compressed_wire_bytes,
            parse_compression,
        )

        spec = parse_compression(compression)
        if spec is not None:
            plan = plan_for(params)
            c_train_bytes = compressed_wire_bytes(plan.total_elems, spec)
            codec = spec.codec
            dense = plan.total_elems * 4
    return RoundMessageLog(
        s_msg_train_bytes=weight_bytes,
        c_msg_train_bytes=c_train_bytes,
        s_msg_aggreg_bytes=weight_bytes,
        c_msg_test_bytes=metric_bytes,
        codec=codec,
        c_msg_train_dense_bytes=dense,
        group_wire_bytes=group_wire,
        group_dense_bytes=group_dense,
    )


def to_cost_model_sizes(log: RoundMessageLog) -> MessageSizes:
    """Bridge real measured sizes into the scheduler's cost model.

    Always the *wire* sizes — with compression enabled the c_msg_train
    term is the compressed frame, which is what the inter-cloud link
    actually carries (the dense equivalent stays a reporting-only
    field)."""
    return MessageSizes(
        s_msg_train_gb=log.s_msg_train_bytes / 1e9,
        s_msg_aggreg_gb=log.s_msg_aggreg_bytes / 1e9,
        c_msg_train_gb=log.c_msg_train_bytes / 1e9,
        c_msg_test_gb=log.c_msg_test_bytes / 1e9,
    )


def model_weight_bytes(params: Any) -> int:
    return int(pytree_num_bytes(params))
