"""FL message accounting (paper §3).

Four message kinds per round: s_msg_train (server -> clients, initial
weights), c_msg_train (client -> server, updated weights), s_msg_aggreg
(server -> clients, aggregated weights), c_msg_test (client -> server, ML
metrics). Byte sizes are measured from the *actual serialized payloads*,
and feed the Eq.-6 communication-cost model.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import msgpack

from repro.checkpoint.serializer import pytree_num_bytes, serialize_pytree
from repro.core.application_model import MessageSizes


@dataclasses.dataclass(frozen=True)
class RoundMessageLog:
    s_msg_train_bytes: int
    c_msg_train_bytes: int
    s_msg_aggreg_bytes: int
    c_msg_test_bytes: int

    def total_bytes(self, n_clients: int) -> int:
        """Bytes on the wire for a full round with n_clients."""
        return n_clients * (
            self.s_msg_train_bytes
            + self.c_msg_train_bytes
            + self.s_msg_aggreg_bytes
            + self.c_msg_test_bytes
        )


def serialize_metrics(metrics: Dict[str, float]) -> bytes:
    """The wire form of a ``c_msg_test`` payload (msgpack, like weights)."""
    return msgpack.packb(
        {str(k): float(v) for k, v in metrics.items()}, use_bin_type=True
    )


def measure_messages(params: Any, metrics_example: Dict[str, float]) -> RoundMessageLog:
    """Measure real serialized sizes for one round's message set.

    All four messages are measured from their actual serialized payloads
    — the metrics dict included, so Eq.-6 communication costs never mix
    measured weight transfers with a guessed per-key constant."""
    weight_bytes = len(serialize_pytree(params))
    metric_bytes = len(serialize_metrics(metrics_example))
    return RoundMessageLog(
        s_msg_train_bytes=weight_bytes,
        c_msg_train_bytes=weight_bytes,
        s_msg_aggreg_bytes=weight_bytes,
        c_msg_test_bytes=metric_bytes,
    )


def to_cost_model_sizes(log: RoundMessageLog) -> MessageSizes:
    """Bridge real measured sizes into the scheduler's cost model."""
    return MessageSizes(
        s_msg_train_gb=log.s_msg_train_bytes / 1e9,
        s_msg_aggreg_gb=log.s_msg_aggreg_bytes / 1e9,
        c_msg_train_gb=log.c_msg_train_bytes / 1e9,
        c_msg_test_gb=log.c_msg_test_bytes / 1e9,
    )


def model_weight_bytes(params: Any) -> int:
    return pytree_num_bytes(params)
