"""FL server: round orchestration per the paper's §3 protocol.

Each round:
  training phase   — send s_msg_train (current weights) to every client;
                     each trains locally and returns c_msg_train;
                     server aggregates (FedAvg) through the fused
                     `AggregationEngine` (one jitted reduce per round;
                     Pallas kernel + buffer donation on TPU).
  evaluation phase — send s_msg_aggreg (aggregated weights); clients
                     evaluate and return c_msg_test metrics; server
                     aggregates metrics and starts the next round.

Cross-silo semantics: the server *always waits for all clients* before the
next round (paper §4.3 — skipping a silo every round would bias learning).
Checkpointing follows §4.3: server checkpoint every X rounds with async
off-VM transfer; clients store the aggregated weights each round. The
`fault_hook` lets tests/examples revoke tasks mid-execution; recovery uses
`repro.checkpoint.resolve_freshest`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax

from repro.checkpoint import (
    ClientCheckpointManager,
    ServerCheckpointManager,
    resolve_freshest,
)
from .agg_engine import AggregationEngine
from .aggregation import aggregate_metrics
from .client import ClientResult, EvalResult, FLClient
from .messages import RoundMessageLog, measure_messages


@dataclasses.dataclass
class RoundRecord:
    round_idx: int
    train_time_s: float
    eval_time_s: float
    checkpoint_time_s: float
    metrics: Dict[str, float]
    message_log: Optional[RoundMessageLog]
    restarted_from: Optional[str] = None
    agg_time_s: float = 0.0
    # Async round-engine accounting (virtual clock, see async_server):
    # per-client c_msg_train fold-completion times, the dispatch->params
    # span, and the server's idle share of that span.  The sync barrier
    # path reports every fold completing at the fused-reduce finish.
    fold_times_s: Dict[str, float] = dataclasses.field(default_factory=dict)
    round_span_s: float = 0.0
    idle_s: float = 0.0
    # Deadline-driven partial rounds (async_server.RoundDeadline): the
    # effective (quorum-extended) close time, the silos whose late update
    # was parked for the next round, and the stale silos folded into this
    # round's average with their staleness discount applied.
    deadline_s: Optional[float] = None
    carried_over: List[str] = dataclasses.field(default_factory=list)
    carried_in: List[str] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class FLRunResult:
    rounds: List[RoundRecord]
    final_params: Any
    total_time_s: float

    @property
    def final_metrics(self) -> Dict[str, float]:
        return self.rounds[-1].metrics if self.rounds else {}


class FLServer:
    def __init__(
        self,
        clients: Sequence[FLClient],
        initial_params: Any,
        server_ckpt: Optional[ServerCheckpointManager] = None,
        client_ckpts: Optional[Dict[str, ClientCheckpointManager]] = None,
        fault_hook: Optional[Callable[[int], Optional[str]]] = None,
        measure_round_messages: bool = False,
        agg_engine: Optional[AggregationEngine] = None,
    ) -> None:
        self.clients = list(clients)
        self.params = initial_params
        self.agg_engine = agg_engine if agg_engine is not None else AggregationEngine()
        self.server_ckpt = server_ckpt
        self.client_ckpts = client_ckpts or {}
        self.fault_hook = fault_hook
        self.measure_round_messages = measure_round_messages
        self.start_round = 1
        self._round_engine = None  # lazily built (see _fold_phase)

    # ------------------------------------------------------------------
    def run(self, n_rounds: int) -> FLRunResult:
        t_start = time.monotonic()
        records: List[RoundRecord] = []
        r = self.start_round
        while r <= n_rounds:
            restarted_from = None
            # Fault injection point: hook returns "s" or a client id to kill.
            if self.fault_hook is not None:
                victim = self.fault_hook(r)
                if victim == "s":
                    restarted_from = self._recover_server()

            rec = self._run_round(r, restarted_from)
            records.append(rec)
            r += 1

        if self.server_ckpt is not None:
            self.server_ckpt.wait_for_transfers()
        return FLRunResult(
            rounds=records,
            final_params=self.params,
            total_time_s=time.monotonic() - t_start,
        )

    # ------------------------------------------------------------------
    def _run_round(self, round_idx: int, restarted_from: Optional[str]) -> RoundRecord:
        # Training phase: s_msg_train -> local train -> c_msg_train.
        t0 = time.monotonic()
        results: List[ClientResult] = [c.train(self.params) for c in self.clients]
        t_agg = time.monotonic()
        fold = self._fold_phase(round_idx, results)
        self.params = fold.params
        jax.block_until_ready(self.params)
        agg_time = time.monotonic() - t_agg
        train_time = time.monotonic() - t0

        # Evaluation phase: s_msg_aggreg -> local eval -> c_msg_test.
        t1 = time.monotonic()
        evals: List[EvalResult] = [c.evaluate(self.params) for c in self.clients]
        metrics = aggregate_metrics(
            [e.metrics for e in evals], [max(e.n_samples, 1) for e in evals]
        )
        eval_time = time.monotonic() - t1

        # Checkpointing (§4.3).
        t2 = time.monotonic()
        for c in self.clients:
            mgr = self.client_ckpts.get(c.client_id)
            if mgr is not None:
                mgr.save(round_idx, self.params)
        if self.server_ckpt is not None and self.server_ckpt.should_checkpoint(round_idx):
            self.server_ckpt.save(round_idx, self.params)
        ckpt_time = time.monotonic() - t2

        log = measure_messages(self.params, metrics) if self.measure_round_messages else None
        return RoundRecord(
            round_idx=round_idx,
            train_time_s=train_time,
            eval_time_s=eval_time,
            checkpoint_time_s=ckpt_time,
            metrics=metrics,
            message_log=log,
            restarted_from=restarted_from,
            agg_time_s=agg_time,
            fold_times_s=fold.fold_times,
            round_span_s=fold.round_span_s,
            idle_s=fold.idle_s,
            deadline_s=fold.deadline_s,
            carried_over=list(fold.carried_over),
            carried_in=list(fold.carried_in),
        )

    # ------------------------------------------------------------------
    def _fold_phase(self, round_idx: int, results: Sequence[ClientResult]):
        """Aggregate one round's c_msg_train set.

        The barrier protocol is the degenerate (all-messages-at-dispatch)
        schedule of the async round engine, so the sync server routes
        through the same engine; AsyncFLServer overrides only the
        schedule/policy (see async_server.AsyncFLServer)."""
        # Lazy import: async_server imports RoundRecord/FLServer from here.
        from .async_server import AsyncRoundEngine, InstantSchedule

        if self._round_engine is None:
            self._round_engine = AsyncRoundEngine(self.agg_engine)
        return self._round_engine.fold_round(round_idx, results, InstantSchedule())

    # ------------------------------------------------------------------
    def _recover_server(self) -> str:
        """Server VM died: restore weights from the freshest checkpoint
        (paper §4.3 rule) and rewind the round counter accordingly.

        The freshest-wins resolution runs whenever *any* checkpoint source
        exists: client checkpoints alone can restore the server (the paper's
        "the FL server ... waits for any client to send its weights"), so a
        missing ServerCheckpointManager must not skip resolution."""
        if self.server_ckpt is None and not self.client_ckpts:
            source, info = "none", None
        else:
            source, info = resolve_freshest(self.server_ckpt, self.client_ckpts)
        if source == "none" or info is None:
            # No checkpoint anywhere: restart from scratch semantics is the
            # caller's job; here we just keep current in-memory weights.
            return "none"
        if source == "server":
            _, self.params = self.server_ckpt.restore(self.params, info)
        else:
            cid = source.split(":", 1)[1]
            _, self.params = self.client_ckpts[cid].restore(self.params)
        return source
