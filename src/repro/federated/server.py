"""FL server: round orchestration per the paper's §3 protocol.

Each round:
  training phase   — send s_msg_train (current weights) to every client;
                     each trains locally and returns c_msg_train;
                     server aggregates (FedAvg) through the fused
                     `AggregationEngine` (one jitted reduce per round;
                     Pallas kernel + buffer donation on TPU).
  evaluation phase — send s_msg_aggreg (aggregated weights); clients
                     evaluate and return c_msg_test metrics; server
                     aggregates metrics and starts the next round.

Cross-silo semantics: the server *always waits for all clients* before the
next round (paper §4.3 — skipping a silo every round would bias learning).
Checkpointing follows §4.3: server checkpoint every X rounds with async
off-VM transfer; clients store the aggregated weights each round. The
`fault_hook` lets tests/examples revoke tasks mid-execution; recovery uses
`repro.checkpoint.resolve_freshest`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, TYPE_CHECKING

import jax

from repro.checkpoint import (
    ClientCheckpointManager,
    ServerCheckpointManager,
    resolve_freshest,
)
from repro.core.events import (
    CheckpointSaved,
    EventBus,
    RecoveryCompleted,
    RoundDispatched,
)
from .agg_engine import AggregationEngine
from .aggregation import aggregate_metrics
from .client import ClientResult, EvalResult, FLClient
from .messages import RoundMessageLog, measure_messages

if TYPE_CHECKING:
    from .async_server import AsyncRoundEngine, FoldReport


@dataclasses.dataclass
class RoundRecord:
    round_idx: int
    train_time_s: float
    eval_time_s: float
    checkpoint_time_s: float
    metrics: Dict[str, float]
    message_log: Optional[RoundMessageLog]
    restarted_from: Optional[str] = None
    agg_time_s: float = 0.0
    # Async round-engine accounting (virtual clock, see async_server):
    # per-client c_msg_train fold-completion times, the dispatch->params
    # span, and the server's idle share of that span.  The sync barrier
    # path reports every fold completing at the fused-reduce finish.
    fold_times_s: Dict[str, float] = dataclasses.field(default_factory=dict)
    round_span_s: float = 0.0
    idle_s: float = 0.0
    # Deadline-driven partial rounds (async_server.RoundDeadline): the
    # effective (quorum-extended) close time, the silos whose late update
    # was parked for the next round, and the stale silos folded into this
    # round's average with their staleness discount applied.
    deadline_s: Optional[float] = None
    carried_over: List[str] = dataclasses.field(default_factory=list)
    carried_in: List[str] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class FLRunResult:
    rounds: List[RoundRecord]
    final_params: Any
    total_time_s: float

    @property
    def final_metrics(self) -> Dict[str, float]:
        return self.rounds[-1].metrics if self.rounds else {}


class FLServer:
    def __init__(
        self,
        clients: Sequence[FLClient],
        initial_params: Any,
        server_ckpt: Optional[ServerCheckpointManager] = None,
        client_ckpts: Optional[Dict[str, ClientCheckpointManager]] = None,
        fault_hook: Optional[Callable[[int], Optional[str]]] = None,
        measure_round_messages: bool = False,
        agg_engine: Optional[AggregationEngine] = None,
        bus: Optional[EventBus] = None,
        post_round_hook: Optional[Callable[[int, Any], Optional[Any]]] = None,
    ) -> None:
        self.clients = list(clients)
        self.params = initial_params
        self.agg_engine = agg_engine if agg_engine is not None else AggregationEngine()
        self.server_ckpt = server_ckpt
        self.client_ckpts = client_ckpts or {}
        self.fault_hook = fault_hook
        self.measure_round_messages = measure_round_messages
        self.start_round = 1
        # Server-side post-aggregation transform, called as
        # hook(round_idx, params) right after the fold; a non-None return
        # replaces the global weights before evaluation/checkpointing.
        # The adapter-FL use: periodically merge LoRA factors into the
        # frozen base (models.fl_models.merge_hook).
        self.post_round_hook = post_round_hook
        # lazily built (see _fold_phase)
        self._round_engine: Optional["AsyncRoundEngine"] = None
        # Control-plane bus: the round engine publishes fold-level events
        # on the round's virtual clock; the server publishes lifecycle
        # events (dispatch, checkpoints, recovery) on the wall clock
        # relative to run() start.  One bus, one trace vocabulary —
        # shared with the simulator (repro.core.events).
        self.bus = bus if bus is not None else EventBus()
        self._wall_t0 = time.monotonic()

    def _wall(self) -> float:
        return time.monotonic() - self._wall_t0

    # ------------------------------------------------------------------
    def run(self, n_rounds: int) -> FLRunResult:
        t_start = time.monotonic()
        self._wall_t0 = t_start
        records: List[RoundRecord] = []
        r = self.start_round
        while r <= n_rounds:
            restarted_from = None
            # Fault injection point: hook returns "s" or a client id to kill.
            if self.fault_hook is not None:
                victim = self.fault_hook(r)
                if victim == "s":
                    restarted_from = self._recover_server(resume_round=r)

            self.bus.publish(RoundDispatched(self._wall(), r, len(self.clients)))
            rec = self._run_round(r, restarted_from)
            records.append(rec)
            r += 1

        if self.server_ckpt is not None:
            self.server_ckpt.wait_for_transfers()
        return FLRunResult(
            rounds=records,
            final_params=self.params,
            total_time_s=time.monotonic() - t_start,
        )

    # ------------------------------------------------------------------
    def _run_round(self, round_idx: int, restarted_from: Optional[str]) -> RoundRecord:
        # Training phase: s_msg_train -> local train -> c_msg_train.
        t0 = time.monotonic()
        results: List[ClientResult] = [c.train(self.params) for c in self.clients]
        t_agg = time.monotonic()
        fold = self._fold_phase(round_idx, results)
        self.params = fold.params
        jax.block_until_ready(self.params)
        if self.post_round_hook is not None:
            merged = self.post_round_hook(round_idx, self.params)
            if merged is not None:
                self.params = merged
                jax.block_until_ready(self.params)
        agg_time = time.monotonic() - t_agg
        train_time = time.monotonic() - t0

        # Evaluation phase: s_msg_aggreg -> local eval -> c_msg_test.
        t1 = time.monotonic()
        evals: List[EvalResult] = [c.evaluate(self.params) for c in self.clients]
        metrics = aggregate_metrics(
            [e.metrics for e in evals], [max(e.n_samples, 1) for e in evals]
        )
        eval_time = time.monotonic() - t1

        # Checkpointing (§4.3).  Client and server saves are timed
        # separately so each CheckpointSaved event carries only its own
        # location's overhead (trace consumers sum overhead_s).
        t2 = time.monotonic()
        saved_client = False
        for c in self.clients:
            mgr = self.client_ckpts.get(c.client_id)
            if mgr is not None:
                mgr.save(round_idx, self.params)
                saved_client = True
        client_ckpt_time = time.monotonic() - t2
        t3 = time.monotonic()
        saved_server = False
        if self.server_ckpt is not None and self.server_ckpt.should_checkpoint(round_idx):
            self.server_ckpt.save(round_idx, self.params)
            saved_server = True
        server_ckpt_time = time.monotonic() - t3
        ckpt_time = client_ckpt_time + server_ckpt_time
        if saved_client:
            self.bus.publish(
                CheckpointSaved(self._wall(), round_idx, "client_local",
                                client_ckpt_time)
            )
        if saved_server:
            self.bus.publish(
                CheckpointSaved(self._wall(), round_idx, "server_remote",
                                server_ckpt_time)
            )

        log = None
        if self.measure_round_messages:
            # AsyncFLServer sets _compression when the wire path is
            # compressed and _schema when updates are structured; the log
            # then carries wire vs dense c_msg_train (and per-group maps).
            log = measure_messages(
                self.params, metrics,
                compression=getattr(self, "_compression", None),
                schema=getattr(self, "_schema", None),
            )
        return RoundRecord(
            round_idx=round_idx,
            train_time_s=train_time,
            eval_time_s=eval_time,
            checkpoint_time_s=ckpt_time,
            metrics=metrics,
            message_log=log,
            restarted_from=restarted_from,
            agg_time_s=agg_time,
            fold_times_s=fold.fold_times,
            round_span_s=fold.round_span_s,
            idle_s=fold.idle_s,
            deadline_s=fold.deadline_s,
            carried_over=list(fold.carried_over),
            carried_in=list(fold.carried_in),
        )

    # ------------------------------------------------------------------
    def _fold_phase(
        self, round_idx: int, results: Sequence[ClientResult]
    ) -> "FoldReport":
        """Aggregate one round's c_msg_train set.

        The barrier protocol is the degenerate (all-messages-at-dispatch)
        schedule of the async round engine, so the sync server routes
        through the same engine; AsyncFLServer overrides only the
        schedule/policy (see async_server.AsyncFLServer)."""
        # Lazy import: async_server imports RoundRecord/FLServer from here.
        from .async_server import AsyncRoundEngine, InstantSchedule

        if self._round_engine is None:
            self._round_engine = AsyncRoundEngine(self.agg_engine, bus=self.bus)
        return self._round_engine.fold_round(round_idx, results, InstantSchedule())

    # ------------------------------------------------------------------
    def _recover_server(self, resume_round: Optional[int] = None) -> str:
        """Server VM died: restore weights from the freshest checkpoint
        (paper §4.3 rule) and rewind the round counter accordingly.

        The freshest-wins resolution runs whenever *any* checkpoint source
        exists: client checkpoints alone can restore the server (the paper's
        "the FL server ... waits for any client to send its weights"), so a
        missing ServerCheckpointManager must not skip resolution.

        ``resume_round`` is the round the run loop (re-)executes after the
        restore (the current round on the live path); it only feeds the
        RecoveryCompleted trace event."""
        resume = resume_round if resume_round is not None else self.start_round
        if self.server_ckpt is None and not self.client_ckpts:
            source, info = "none", None
        else:
            source, info = resolve_freshest(self.server_ckpt, self.client_ckpts)
        if source == "none" or info is None:
            # No checkpoint anywhere: restart from scratch semantics is the
            # caller's job; here we just keep current in-memory weights.
            self.bus.publish(
                RecoveryCompleted(self._wall(), "s", resume, 0.0, "none")
            )
            return "none"
        if source == "server":
            assert self.server_ckpt is not None  # resolve_freshest contract
            _, self.params = self.server_ckpt.restore(self.params, info)
        else:
            cid = source.split(":", 1)[1]
            _, self.params = self.client_ckpts[cid].restore(self.params)
        # The documented trace vocabulary (events.py / the simulator's
        # CheckpointRecord.location): server_remote | client_local:<cid>.
        restored = (
            "server_remote" if source == "server"
            else f"client_local:{source.split(':', 1)[1]}"
        )
        self.bus.publish(
            RecoveryCompleted(self._wall(), "s", resume, 0.0, restored)
        )
        return source
