"""Wall-clock socket transport: real ``FLClient`` workers as a bus driver.

The paper's proof of concept (§5.7) runs Cross-Silo FL over real
networks (AWS + GCP); every other driver in this repo advances a
virtual clock.  This module closes that gap with a *third* driver of
the shared control plane: a length-prefixed loopback/TCP transport
(:class:`SocketTransport`) carrying the §3 message set — ``s_msg_train``
/ ``c_msg_train`` / ``s_msg_aggreg`` / ``c_msg_test`` — between a
:class:`LiveRoundDriver` and real ``FLClient`` workers, each running the
blocking :func:`run_client_worker` loop in its own thread
(:class:`ThreadWorkerPool`, the CI-friendly default: same wire protocol,
framing, and crash semantics, no process spawn cost) or OS process
(:class:`ProcessWorkerPool`, ``multiprocessing`` spawn).

Design rule: the live path is **just another bus driver**.  The driver
records each reply's measured wall-clock arrival offset and replays the
round through the *existing* :class:`~repro.federated.async_server.
AsyncRoundEngine` via a :class:`RecordedSchedule` — so the
``StreamingAggregator`` fold path, the :class:`~repro.federated.
async_server.RoundDeadline` policies (including builder-bridged
:class:`~repro.federated.async_server.CallableDeadline` specs), the
carry-over buffer, §4.3 re-request-or-exclude recovery, and the §4.4
:class:`~repro.core.control_plane.StragglerTracker` escalation all run
unchanged on measured times, and the bus carries the same typed
vocabulary (RoundDispatched, UpdateArrived, UpdateFolded,
RevocationOccurred, DeadlineExpired, StragglerEscalated, RoundClosed)
as the virtual-clock drivers.  ``scripts/trace_dump.format_trace``
renders a live trace and a simulated one identically; the parity is
pinned by ``tests/test_transport.py``.

Fault mapping (§4.3 / §4.4):

* **crash** — a worker whose ``train`` raises drops its connection; the
  driver sees EOF mid-round and, under ``on_revocation="rerequest"``,
  physically restarts the worker and resends ``s_msg_train``.  The
  *measured* re-arrival is replayed through the engine via
  ``ClientArrival.re_arrival_s`` (RevocationOccurred + attempt-2
  UpdateArrived in the trace).  With the re-request budget exhausted
  (or ``"exclude"``) the silo is excluded from the round and dropped
  from the cohort.
* **reply timeout** — a silo that misses ``reply_timeout_s`` is treated
  as a §4.3 suspected fault for the round (RevocationOccurred with an
  infinite recorded re-arrival => excluded) but *stays in the cohort*:
  its worker is still alive, stale replies are discarded by round tag,
  and consecutive timeouts advance the engine's shared
  ``StragglerTracker`` toward a §4.4 ``StragglerEscalated`` event and
  the ``on_straggler`` hook — the same escalation contract as
  ``AsyncFLServer``.

Communication costs (Eq. 6) are fed back from *measured* payloads: each
round's :class:`~repro.federated.messages.RoundMessageLog` carries the
actual serialized byte counts seen on the wire, and an attached
``CostModel`` is updated through
:func:`~repro.federated.messages.to_cost_model_sizes` after every round.
"""
from __future__ import annotations

import dataclasses
import math
import multiprocessing
import queue
import random
import selectors
import socket
import struct
import threading
import time
from typing import (
    Any,
    Callable,
    Dict,
    List,
    Mapping,
    MutableMapping,
    Optional,
    Protocol,
    Sequence,
    Set,
    Tuple,
    runtime_checkable,
)

import jax
import msgpack
import numpy as np

from repro.checkpoint import resolve_freshest
from repro.checkpoint.serializer import (
    DeserializationError,
    deserialize_pytree,
    serialize_pytree,
)
from repro.core.cost_model import Assignment
from repro.core.events import (
    CheckpointSaved,
    EventBus,
    FaultInjected,
    RecoveryCompleted,
    RoundDispatched,
    StragglerEscalated,
    VMReplaced,
)
from .agg_engine import AggregationEngine
from .aggregation import aggregate_metrics
from .async_server import (
    ArrivalSchedule,
    AsyncRoundEngine,
    ClientArrival,
    FoldReport,
    RoundDeadline,
)
from .chaos import DRIVER_KINDS, FaultPlan, corrupt_latest_checkpoint
from .client import ClientResult
from .messages import RoundMessageLog, serialize_metrics, to_cost_model_sizes
from .server import FLRunResult, RoundRecord

__all__ = [
    "LiveRoundDriver",
    "ProcessWorkerPool",
    "ReconnectPolicy",
    "RecordedSchedule",
    "SocketTransport",
    "ThreadWorkerPool",
    "TransportEvent",
    "WorkerPool",
    "run_client_worker",
]


# ---------------------------------------------------------------------------
# Wire protocol: length-prefixed frames
# ---------------------------------------------------------------------------

# Message kinds — the §3 vocabulary plus session control.
MSG_HELLO = "hello"
MSG_S_TRAIN = "s_msg_train"
MSG_C_TRAIN = "c_msg_train"
MSG_S_AGGREG = "s_msg_aggreg"
MSG_C_TEST = "c_msg_test"
MSG_SHUTDOWN = "shutdown"
# Liveness probes (server -> worker -> server).  A worker answers PING
# from its receive loop even while a train/evaluate is computing, so a
# missing PONG means the *silo* is dead or wedged — not merely slow.
MSG_PING = "ping"
MSG_PONG = "pong"

# Frame = 8-byte prefix (header length, payload length, both u32 BE)
# + msgpack header + raw payload (serialized pytree / metrics blob).
_PREFIX = struct.Struct(">II")
_RECV_CHUNK = 1 << 20


def _pack_header(header: Mapping[str, Any]) -> bytes:
    return bytes(msgpack.packb(dict(header), use_bin_type=True))


def _unpack_header(blob: bytes) -> Dict[str, Any]:
    out = msgpack.unpackb(blob, raw=False)
    if not isinstance(out, dict):
        raise ValueError(f"malformed frame header: {out!r}")
    return dict(out)


def send_frame(
    sock: socket.socket, header: Mapping[str, Any], payload: bytes = b""
) -> int:
    """Write one frame; returns the bytes put on the wire (prefix incl.)."""
    head = _pack_header(header)
    sock.sendall(_PREFIX.pack(len(head), len(payload)) + head + payload)
    return _PREFIX.size + len(head) + len(payload)


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    """Blocking read of exactly n bytes; None on a clean EOF at a frame
    boundary (mid-frame EOF raises ConnectionError)."""
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            if not buf:
                return None
            raise ConnectionError("connection closed mid-frame")
        buf.extend(chunk)
    return bytes(buf)


def recv_frame(sock: socket.socket) -> Optional[Tuple[Dict[str, Any], bytes]]:
    """Blocking read of one frame; None on clean EOF (peer closed)."""
    prefix = _recv_exact(sock, _PREFIX.size)
    if prefix is None:
        return None
    head_len, payload_len = _PREFIX.unpack(prefix)
    head = _recv_exact(sock, head_len) if head_len else b""
    if head is None:
        raise ConnectionError("connection closed mid-frame")
    payload = _recv_exact(sock, payload_len) if payload_len else b""
    if payload is None:
        raise ConnectionError("connection closed mid-frame")
    return _unpack_header(head), payload


# ---------------------------------------------------------------------------
# Server-side transport
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TransportEvent:
    """One observation from :meth:`SocketTransport.poll`.

    ``kind``: ``"message"`` (a complete frame from an identified client),
    ``"joined"`` (a worker's hello was accepted — first connect or a
    §4.3 restart rejoin), or ``"disconnect"`` (EOF/reset: the silo
    crashed or shut down).  ``wire_bytes`` counts the frame's full
    on-the-wire size (prefix + header + payload) for message events.
    """

    kind: str
    client_id: str
    header: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    payload: bytes = b""
    wire_bytes: int = 0


class _ConnState:
    """Per-connection receive buffer + incremental frame parser."""

    def __init__(self, sock: socket.socket) -> None:
        self.sock = sock
        self.buf = bytearray()
        self.client_id: Optional[str] = None

    def parse_frames(self) -> List[Tuple[Dict[str, Any], bytes, int]]:
        frames: List[Tuple[Dict[str, Any], bytes, int]] = []
        while len(self.buf) >= _PREFIX.size:
            head_len, payload_len = _PREFIX.unpack(bytes(self.buf[: _PREFIX.size]))
            total = _PREFIX.size + head_len + payload_len
            if len(self.buf) < total:
                break
            head = bytes(self.buf[_PREFIX.size:_PREFIX.size + head_len])
            payload = bytes(self.buf[_PREFIX.size + head_len:total])
            del self.buf[:total]
            frames.append((_unpack_header(head), payload, total))
        return frames


class SocketTransport:
    """Length-prefixed TCP transport multiplexing one server over N silos.

    The server listens on ``host:port`` (port 0 = ephemeral loopback —
    the CI default); each worker connects and identifies itself with a
    hello frame.  :meth:`poll` drives a ``selectors`` loop that accepts
    new connections (first joins and §4.3 restart rejoins alike), parses
    complete frames out of per-connection buffers, and surfaces
    disconnects — the driver's crash signal.  Sends are blocking with a
    ``send_timeout_s`` bound so a wedged silo cannot hang the server.
    """

    def __init__(
        self,
        host: str = "127.0.0.1",
        port: int = 0,
        send_timeout_s: float = 30.0,
    ) -> None:
        self.host = host
        self.port = port
        self.send_timeout_s = send_timeout_s
        self._listener: Optional[socket.socket] = None
        self._selector = selectors.DefaultSelector()
        self._conns: Dict[str, _ConnState] = {}

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> Tuple[str, int]:
        """Bind + listen; returns the (host, port) workers connect to."""
        if self._listener is not None:
            return self.address
        listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        listener.bind((self.host, self.port))
        listener.listen()
        listener.setblocking(False)
        self._listener = listener
        self._selector.register(listener, selectors.EVENT_READ, None)
        return self.address

    @property
    def address(self) -> Tuple[str, int]:
        if self._listener is None:
            raise RuntimeError("transport not started: call start() first")
        addr = self._listener.getsockname()
        return str(addr[0]), int(addr[1])

    def close(self) -> None:
        for state in list(self._conns.values()):
            self._drop(state)
        self._conns.clear()
        if self._listener is not None:
            try:
                self._selector.unregister(self._listener)
            except (KeyError, ValueError):
                pass
            self._listener.close()
            self._listener = None
        self._selector.close()

    # -- connection registry ----------------------------------------------
    @property
    def client_ids(self) -> List[str]:
        return sorted(self._conns)

    def is_live(self, client_id: str) -> bool:
        return client_id in self._conns

    def disconnect(self, client_id: str) -> bool:
        """Force-sever a silo's connection (the chaos ``disconnect`` /
        ``revocation`` faults, and the liveness detector's hang verdict).

        The worker observes EOF and dies — exactly the §4.3 crash signal
        a real revocation produces.  Returns False when the silo was not
        connected.  No ``disconnect`` TransportEvent is emitted (the
        caller initiated the drop, so it already knows)."""
        state = self._conns.get(client_id)
        if state is None:
            return False
        self._drop(state)
        return True

    def _drop(self, state: _ConnState) -> None:
        try:
            self._selector.unregister(state.sock)
        except (KeyError, ValueError):
            pass
        try:
            state.sock.close()
        except OSError:
            pass
        if state.client_id is not None and (
            self._conns.get(state.client_id) is state
        ):
            del self._conns[state.client_id]

    # -- sending -----------------------------------------------------------
    def send(
        self, client_id: str, header: Mapping[str, Any], payload: bytes = b""
    ) -> int:
        """Send one frame to a connected silo; returns wire bytes.

        Raises ``ConnectionError`` when the silo is not connected or the
        send times out / fails — callers map that onto the §4.3 crash
        path exactly like an EOF."""
        state = self._conns.get(client_id)
        if state is None:
            raise ConnectionError(f"client {client_id!r} is not connected")
        sock = state.sock
        try:
            sock.settimeout(self.send_timeout_s)
            return send_frame(sock, header, payload)
        except (OSError, socket.timeout) as exc:
            self._drop(state)
            raise ConnectionError(
                f"send to client {client_id!r} failed: {exc}"
            ) from exc
        finally:
            try:
                sock.setblocking(False)
            except OSError:
                pass

    # -- polling -----------------------------------------------------------
    def poll(self, timeout_s: Optional[float]) -> List[TransportEvent]:
        """Advance the selector loop once; returns all transport events
        observed (possibly none on timeout)."""
        if self._listener is None:
            raise RuntimeError("transport not started: call start() first")
        events: List[TransportEvent] = []
        for key, _mask in self._selector.select(timeout_s):
            if key.data is None:  # the listener
                self._accept(events)
            else:
                self._read(key.data, events)
        return events

    def _accept(self, events: List[TransportEvent]) -> None:
        assert self._listener is not None
        while True:
            try:
                conn, _addr = self._listener.accept()
            except BlockingIOError:
                return
            conn.setblocking(False)
            state = _ConnState(conn)
            self._selector.register(conn, selectors.EVENT_READ, state)

    def _read(self, state: _ConnState, events: List[TransportEvent]) -> None:
        closed = False
        try:
            chunk = state.sock.recv(_RECV_CHUNK)
            if not chunk:
                closed = True
            else:
                state.buf.extend(chunk)
        except BlockingIOError:
            return
        except OSError:
            closed = True

        for header, payload, wire in state.parse_frames():
            if state.client_id is None:
                if header.get("kind") != MSG_HELLO or "client_id" not in header:
                    closed = True
                    break
                cid = str(header["client_id"])
                state.client_id = cid
                stale = self._conns.get(cid)
                if stale is not None and stale is not state:
                    self._drop(stale)
                self._conns[cid] = state
                events.append(TransportEvent("joined", cid))
            else:
                events.append(
                    TransportEvent(
                        "message", state.client_id, header, payload, wire
                    )
                )

        if closed:
            cid_opt = state.client_id
            self._drop(state)
            if cid_opt is not None:
                events.append(TransportEvent("disconnect", cid_opt))

    def wait_for_clients(
        self, client_ids: Sequence[str], timeout_s: float = 30.0
    ) -> List[TransportEvent]:
        """Block until every id has said hello (startup barrier); returns
        any non-join events observed while waiting."""
        spill: List[TransportEvent] = []
        deadline = time.monotonic() + timeout_s
        missing = set(client_ids) - set(self._conns)
        while missing:
            remaining = deadline - time.monotonic()
            if remaining <= 0.0:
                raise TimeoutError(
                    f"workers never connected: {sorted(missing)}"
                )
            for ev in self.poll(remaining):
                if ev.kind != "joined":
                    spill.append(ev)
            missing = set(client_ids) - set(self._conns)
        return spill


# ---------------------------------------------------------------------------
# Worker side
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ReconnectPolicy:
    """Exponential backoff + jitter for worker connects (bounded retries).

    A replacement VM coming up while the server is mid-restart (or a
    transient network partition) should not kill the worker on its first
    refused connect: :func:`run_client_worker` retries up to
    ``max_attempts`` times, sleeping ``base_delay_s * multiplier**k``
    (capped at ``max_delay_s``) between attempts, each delay scaled by a
    uniform ±``jitter_frac`` factor.  The jitter is drawn from
    ``random.Random(f"{seed}:{salt}")`` — per-silo deterministic, so a
    chaos run replays the exact same backoff timeline."""

    max_attempts: int = 3
    base_delay_s: float = 0.05
    max_delay_s: float = 2.0
    multiplier: float = 2.0
    jitter_frac: float = 0.25
    seed: int = 0

    def __post_init__(self) -> None:
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.base_delay_s <= 0.0 or self.max_delay_s <= 0.0:
            raise ValueError("backoff delays must be > 0")
        if self.multiplier < 1.0:
            raise ValueError("multiplier must be >= 1.0")
        if not 0.0 <= self.jitter_frac < 1.0:
            raise ValueError("jitter_frac must be in [0, 1)")

    def delays(self, salt: str = "") -> List[float]:
        """The ``max_attempts - 1`` sleep durations between attempts."""
        rng = random.Random(f"{self.seed}:{salt}")
        out: List[float] = []
        delay = self.base_delay_s
        for _ in range(self.max_attempts - 1):
            jitter = 1.0 + self.jitter_frac * (2.0 * rng.random() - 1.0)
            out.append(min(delay, self.max_delay_s) * jitter)
            delay *= self.multiplier
        return out


def _connect_with_backoff(
    address: Tuple[str, int],
    connect_timeout_s: float,
    reconnect: Optional[ReconnectPolicy],
    salt: str,
) -> Optional[socket.socket]:
    """Connect, retrying per ``reconnect``; None when every attempt fails
    (the server never learns of this worker — the driver's rejoin /
    startup timeout is what notices)."""
    policy = reconnect if reconnect is not None else ReconnectPolicy(max_attempts=1)
    delays = policy.delays(salt)
    for attempt in range(policy.max_attempts):
        try:
            sock = socket.create_connection(
                tuple(address), timeout=connect_timeout_s
            )
            sock.settimeout(None)
            return sock
        except OSError:
            if attempt < len(delays):
                time.sleep(delays[attempt])
    return None


def run_client_worker(
    client: Any,
    template_params: Any,
    address: Tuple[str, int],
    connect_timeout_s: float = 10.0,
    reconnect: Optional[ReconnectPolicy] = None,
    compression: Optional[Any] = None,
    schema: Optional[Any] = None,
) -> None:
    """Blocking worker loop: one real ``FLClient`` behind a socket.

    Speaks the §3 protocol: deserializes ``s_msg_train`` into the
    ``template_params`` structure, trains, replies ``c_msg_train`` with
    the serialized updated weights; deserializes ``s_msg_aggreg``,
    evaluates, replies ``c_msg_test`` with the serialized metrics dict.
    Any exception out of the client (or the socket) drops the connection
    — the server observes EOF, which *is* the §4.3 crash signal.

    Compute runs on a dedicated thread so the receive loop stays
    responsive: ``MSG_PING`` probes are answered immediately even while a
    slow ``train`` is running — which is exactly what lets the driver's
    liveness detector tell a *slow* silo (heartbeats flow) from a *hung*
    one (no PONG past the heartbeat timeout).  Three optional client
    hooks are honoured when present (the chaos harness's
    ``ChaosClient`` provides all three): ``on_round(round_idx, phase)``
    before each compute, ``heartbeat_ok() -> bool`` gating PONG replies,
    and ``mangle_payload(body) -> bytes`` over the serialized reply.
    ``reconnect`` bounds connect retries with backoff + jitter (a single
    attempt when None).

    ``compression`` (a :class:`~repro.federated.compression
    .CompressionSpec` or codec string) switches the ``c_msg_train``
    reply to a compressed delta against the received global weights,
    with the error-feedback residual held in this worker.  The residual
    dies with the worker: a restarted or replaced VM re-encodes from a
    zero residual (slightly more compression error on its next update,
    never a correctness problem).

    ``schema`` (an :class:`~repro.federated.agg_engine.UpdateSchema` or
    group mapping) switches the reply to a *structured* frame carrying
    only the schema's named parameter groups — per-group compressed
    deltas when ``compression`` is also set, raw fp32 group values
    otherwise.  The header gains ``structured``/``group_bytes``/
    ``group_dense`` so the driver's per-group byte accounting is
    measured at the sender.
    """
    sock = _connect_with_backoff(
        address, connect_timeout_s, reconnect, str(client.client_id)
    )
    if sock is None:
        return
    compressor = None
    struct_encoder = None
    if schema is not None:
        from .compression import StructuredCompressor

        # Structured replies subsume plain compression: the encoder
        # applies the codec (when any) per group, with per-group error
        # feedback scoped to this worker.
        struct_encoder = StructuredCompressor(schema, compression)
    elif compression is not None:
        from .compression import ClientCompressor, parse_compression

        spec = parse_compression(compression)
        if spec is not None:
            # Prefer a client-owned compressor (FLClient(compression=...))
            # so the error-feedback residual survives worker restarts
            # over the same client object; else the buffer is scoped to
            # this invocation (a fresh VM starts from zero residual).
            compressor = getattr(client, "compressor", None)
            if compressor is None:
                compressor = ClientCompressor(spec)
    send_lock = threading.Lock()
    jobs: "queue.Queue[Optional[Tuple[Dict[str, Any], bytes]]]" = queue.Queue()

    def _send(header: Mapping[str, Any], payload: bytes = b"") -> None:
        with send_lock:
            send_frame(sock, header, payload)

    def _mangle(body: bytes) -> bytes:
        hook = getattr(client, "mangle_payload", None)
        return bytes(hook(body)) if callable(hook) else body

    def _compute_loop() -> None:
        # A raising client IS the crash model: shut the socket down so
        # the server sees EOF, and exit quietly — the §4.3 recovery
        # story is the server's to tell, not a thread traceback's.
        try:
            while True:
                job = jobs.get()
                if job is None:
                    return
                header, payload = job
                kind = header.get("kind")
                round_idx = int(header.get("round_idx", 0))
                on_round = getattr(client, "on_round", None)
                if callable(on_round):
                    on_round(
                        round_idx, "train" if kind == MSG_S_TRAIN else "eval"
                    )
                params = deserialize_pytree(payload, template_params)
                if kind == MSG_S_TRAIN:
                    result = client.train(params)
                    header_out = {
                        "kind": MSG_C_TRAIN,
                        "round_idx": round_idx,
                        "client_id": str(client.client_id),
                        "n_samples": int(result.n_samples),
                        "train_time_s": float(result.train_time_s),
                    }
                    if struct_encoder is not None:
                        from .agg_engine import plan_for
                        from .compression import serialize_structured

                        supdate = struct_encoder.encode(
                            params, result.params, base_round=round_idx
                        )
                        header_out["structured"] = 1
                        # Dense equivalent = the FULL model's fp32 bytes:
                        # the savings being reported is "groups instead
                        # of the whole pytree", codec included.
                        header_out["dense_bytes"] = int(
                            plan_for(params).total_elems * 4
                        )
                        header_out["group_bytes"] = {
                            str(k): int(v)
                            for k, v in supdate.group_wire_bytes().items()
                        }
                        header_out["group_dense"] = {
                            str(k): int(v)
                            for k, v in supdate.group_dense_bytes().items()
                        }
                        body = serialize_structured(supdate)
                    elif compressor is not None:
                        from .compression import serialize_update

                        update = compressor.encode(params, result.params)
                        header_out["codec"] = update.codec
                        header_out["dense_bytes"] = int(update.dense_bytes)
                        body = serialize_update(update)
                    else:
                        body = serialize_pytree(result.params)
                    _send(header_out, _mangle(body))
                else:
                    ev = client.evaluate(params)
                    _send(
                        {
                            "kind": MSG_C_TEST,
                            "round_idx": round_idx,
                            "client_id": str(client.client_id),
                            "n_samples": int(ev.n_samples),
                            "eval_time_s": float(ev.eval_time_s),
                        },
                        _mangle(serialize_metrics(ev.metrics)),
                    )
        except Exception:  # noqa: BLE001 — crash-to-EOF is the §4.3 contract
            try:
                sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                sock.close()
            except OSError:
                pass

    compute = threading.Thread(
        target=_compute_loop,
        name=f"fl-compute-{client.client_id}",
        daemon=True,
    )
    compute.start()
    try:
        _send({"kind": MSG_HELLO, "client_id": str(client.client_id)})
        while True:
            frame = recv_frame(sock)
            if frame is None:
                return
            header, payload = frame
            kind = header.get("kind")
            if kind == MSG_SHUTDOWN:
                return
            if kind == MSG_PING:
                hb = getattr(client, "heartbeat_ok", None)
                if hb is None or hb():
                    _send(
                        {
                            "kind": MSG_PONG,
                            "client_id": str(client.client_id),
                            "seq": int(header.get("seq", 0)),
                        }
                    )
                continue
            if kind in (MSG_S_TRAIN, MSG_S_AGGREG):
                jobs.put((header, payload))
    except Exception:  # noqa: BLE001 — crash-to-EOF is the §4.3 contract
        pass
    finally:
        jobs.put(None)
        try:
            sock.close()
        except OSError:
            pass


@runtime_checkable
class WorkerPool(Protocol):
    """Where the driver's silos physically run (threads, processes, ...)."""

    @property
    def client_ids(self) -> Sequence[str]: ...

    def launch(self, address: Tuple[str, int]) -> None: ...

    def restart(
        self,
        client_id: str,
        address: Tuple[str, int],
        host: Optional[str] = None,
    ) -> bool: ...

    def host_of(self, client_id: str) -> Optional[str]: ...

    def shutdown(self) -> None: ...


class ThreadWorkerPool:
    """Each ``FLClient`` runs :func:`run_client_worker` on a daemon thread.

    The wire protocol, framing, crash detection, and restart path are
    byte-identical to process mode — only the isolation differs, which
    makes this the CI tier's backend (no spawn/import cost).  A crashed
    worker is restarted by spawning a fresh thread over the *same*
    client object: ``FLClient`` is stateless across rounds (weights flow
    through the server), mirroring a replacement VM restoring from the
    silo's data.

    ``restart(..., host=...)`` records which VM the replacement landed on
    (§4.4: the driver passes ``DynamicScheduler.select_instance``'s
    pick); threads have no real placement, so the host is bookkeeping —
    visible through :meth:`host_of` and the respawned thread's name —
    but it is the same restart-capacity contract process pools honour.
    """

    def __init__(
        self,
        clients: Sequence[Any],
        template_params: Any,
        reconnect: Optional[ReconnectPolicy] = None,
        compression: Optional[Any] = None,
        schema: Optional[Any] = None,
    ) -> None:
        self._clients: Dict[str, Any] = {
            str(c.client_id): c for c in clients
        }
        if len(self._clients) != len(clients):
            raise ValueError("duplicate client_id in worker pool")
        self._template = template_params
        self._reconnect = reconnect
        self._compression = compression
        self._schema = schema
        self._threads: Dict[str, threading.Thread] = {}
        self._hosts: Dict[str, str] = {}

    @property
    def client_ids(self) -> Sequence[str]:
        return list(self._clients)

    def host_of(self, client_id: str) -> Optional[str]:
        return self._hosts.get(client_id)

    def _spawn(self, client_id: str, address: Tuple[str, int]) -> None:
        host = self._hosts.get(client_id)
        name = f"fl-worker-{client_id}" + (f"@{host}" if host else "")
        thread = threading.Thread(
            target=run_client_worker,
            args=(self._clients[client_id], self._template, address),
            kwargs={
                "reconnect": self._reconnect,
                "compression": self._compression,
                "schema": self._schema,
            },
            name=name,
            daemon=True,
        )
        self._threads[client_id] = thread
        thread.start()

    def launch(self, address: Tuple[str, int]) -> None:
        for cid in self._clients:
            self._spawn(cid, address)

    def restart(
        self,
        client_id: str,
        address: Tuple[str, int],
        host: Optional[str] = None,
    ) -> bool:
        if client_id not in self._clients:
            return False
        if host is not None:
            self._hosts[client_id] = host
        self._spawn(client_id, address)
        return True

    def shutdown(self) -> None:
        # Wake compute threads parked in a chaos hang fault first —
        # otherwise the join below waits out the hang bound and the
        # orphan can outlive the interpreter (aborting at exit).
        for client in self._clients.values():
            release = getattr(client, "release", None)
            if callable(release):
                release()
        for thread in self._threads.values():
            thread.join(timeout=5.0)
        self._threads.clear()


def _process_worker_entry(
    factory: Callable[[], Any],
    template_np: Any,
    address: Tuple[str, int],
    reconnect: Optional[ReconnectPolicy] = None,
    compression: Optional[Any] = None,
    schema: Optional[Any] = None,
) -> None:
    """Spawn entry: build the client in the child, then serve."""
    run_client_worker(
        factory(), template_np, address,
        reconnect=reconnect, compression=compression, schema=schema,
    )


class ProcessWorkerPool:
    """Each silo is a real OS process (``multiprocessing`` spawn).

    Clients are built *in the child* from picklable factories, so each
    worker imports jax fresh — true crash isolation at the cost of the
    spawn/import latency (seconds per worker; the slow-tier test covers
    it, CI smoke runs on threads).  Like :class:`ThreadWorkerPool`, a
    §4.4 cross-host ``restart(..., host=...)`` is tracked per silo (the
    replacement process *is* the replacement VM in this model)."""

    def __init__(
        self,
        client_factories: Mapping[str, Callable[[], Any]],
        template_params: Any,
        reconnect: Optional[ReconnectPolicy] = None,
        compression: Optional[Any] = None,
        schema: Optional[Any] = None,
    ) -> None:
        self._factories: Dict[str, Callable[[], Any]] = dict(client_factories)
        # Numpy-ify so the template pickles without device buffers.
        self._template_np = jax.tree.map(np.asarray, template_params)
        self._reconnect = reconnect
        # CompressionSpec is a plain frozen dataclass — pickles into the
        # spawned child with the rest of the worker args.  Schemas with
        # string/sequence selectors (or a dict of them) pickle the same
        # way; callable selectors must be module-level to spawn.
        self._compression = compression
        self._schema = schema
        self._ctx = multiprocessing.get_context("spawn")
        self._procs: Dict[str, Any] = {}
        self._hosts: Dict[str, str] = {}

    @property
    def client_ids(self) -> Sequence[str]:
        return list(self._factories)

    def host_of(self, client_id: str) -> Optional[str]:
        return self._hosts.get(client_id)

    def _spawn(self, client_id: str, address: Tuple[str, int]) -> None:
        host = self._hosts.get(client_id)
        name = f"fl-worker-{client_id}" + (f"@{host}" if host else "")
        proc = self._ctx.Process(
            target=_process_worker_entry,
            args=(
                self._factories[client_id],
                self._template_np,
                address,
                self._reconnect,
                self._compression,
                self._schema,
            ),
            name=name,
            daemon=True,
        )
        self._procs[client_id] = proc
        proc.start()

    def launch(self, address: Tuple[str, int]) -> None:
        for cid in self._factories:
            self._spawn(cid, address)

    def restart(
        self,
        client_id: str,
        address: Tuple[str, int],
        host: Optional[str] = None,
    ) -> bool:
        if client_id not in self._factories:
            return False
        old = self._procs.get(client_id)
        if old is not None and old.is_alive():
            old.terminate()
            old.join(timeout=5.0)
        if host is not None:
            self._hosts[client_id] = host
        self._spawn(client_id, address)
        return True

    def shutdown(self) -> None:
        for proc in self._procs.values():
            if proc.is_alive():
                proc.terminate()
            proc.join(timeout=5.0)
        self._procs.clear()


# ---------------------------------------------------------------------------
# Recorded arrivals -> the existing fold engine
# ---------------------------------------------------------------------------

class RecordedSchedule(ArrivalSchedule):
    """Measured wall-clock arrivals replayed as an ``ArrivalSchedule``.

    This is the whole trick that makes the live transport "just another
    bus driver": the driver measures when each ``c_msg_train`` physically
    landed (and when each silo crashed / recovered), wraps the offsets in
    :class:`~repro.federated.async_server.ClientArrival` records, and
    hands them to the unchanged ``AsyncRoundEngine`` — deadline
    policies, carry-over, recovery, escalation, and the event vocabulary
    all run on *recorded* rather than sampled time."""

    def __init__(self, arrivals: Mapping[str, ClientArrival]) -> None:
        self._arrivals = dict(arrivals)

    def round_arrivals(
        self, round_idx: int, client_ids: Sequence[str]
    ) -> Dict[str, ClientArrival]:
        return {cid: self._arrivals[cid] for cid in client_ids}


# ---------------------------------------------------------------------------
# Live round driver
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _TrainOutcome:
    """One silo's physically-observed training phase for a round."""

    arrival_s: float = math.inf
    revoke_at_s: Optional[float] = None
    attempt: int = 1
    params: Any = None
    n_samples: int = 0
    train_time_s: float = 0.0
    failed: bool = False
    crashed: bool = False    # connection dropped (§4.3 hard-fault signal)
    timed_out: bool = False  # silent past reply_timeout_s (§4.4 evidence)
    payload_bytes: int = 0
    dense_bytes: int = 0     # dense fp32 equivalent of a compressed reply
    # Structured replies: per-group wire / dense fp32 bytes as measured
    # at the sender (None on unstructured rounds).
    group_bytes: Optional[Dict[str, int]] = None
    group_dense: Optional[Dict[str, int]] = None

    def to_arrival(self, client_id: str) -> ClientArrival:
        if self.failed:
            # §4.3 suspected fault: revoked with no recorded re-arrival.
            revoke = self.revoke_at_s if self.revoke_at_s is not None else 0.0
            return ClientArrival(
                client_id, revoke, revoke_at_s=revoke, re_arrival_s=math.inf
            )
        if self.revoke_at_s is not None:
            # Crash mid-round, physically re-requested: replay the
            # measured recovery arrival.
            return ClientArrival(
                client_id,
                self.arrival_s,
                revoke_at_s=self.revoke_at_s,
                re_arrival_s=self.arrival_s,
            )
        return ClientArrival(client_id, self.arrival_s)


class LiveRoundDriver:
    """Wall-clock FL rounds over :class:`SocketTransport` workers.

    Protocol per round (§3): serialize the current weights once, send
    ``s_msg_train`` to the cohort, collect ``c_msg_train`` replies as
    they physically arrive (restarting crashed workers per §4.3), fold
    the round through the shared ``AsyncRoundEngine`` on the recorded
    offsets, then run the evaluation phase (``s_msg_aggreg`` /
    ``c_msg_test``) and report a :class:`~repro.federated.server.
    RoundRecord` — the same record type, fold reports, and bus trace as
    the in-process drivers.

    Parameters mirror ``AsyncFLServer`` where they share meaning:
    ``round_deadline`` / ``carry_discount`` / ``escalate_after`` /
    ``on_revocation`` / ``max_rerequests`` / ``on_straggler``.  Live-only
    knobs: ``reply_timeout_s`` (per-phase wall bound before a silent
    silo becomes a §4.3 suspected fault; None waits indefinitely) and
    ``startup_timeout_s`` (worker hello barrier).  ``cost_model`` is
    updated with each round's *measured* message sizes via
    ``to_cost_model_sizes`` (Eq. 6 on real payloads).

    Hardening knobs (this is the live §4.3/§4.4 surface):

    * ``heartbeat_interval_s`` — PING every pending training silo at
      this cadence; a silo with no PONG for ``heartbeat_timeout_s``
      (default 3x the interval) is declared *hung* — distinguishable
      from slow, whose heartbeats keep flowing — its connection is
      severed and the ordinary §4.3 crash/re-request path takes over.
      None (the default) disables liveness probing.
    * ``scheduler`` + ``placement`` — §4.4 true replacement: every
      worker restart first asks ``scheduler.select_instance`` (the
      ``DynamicScheduler`` heuristic; the revoked VM is excluded from
      candidates) for a *different* host, records it in the mutable
      ``placement`` map, and publishes :class:`~repro.core.events.
      VMReplaced`.  Without a scheduler, restarts rejoin in place.
    * ``server_ckpt`` / ``client_ckpts`` — the §4.3 checkpoint story on
      the live path, mirroring ``FLServer``: clients store each round's
      aggregate, the server checkpoints per its interval (async off-VM
      copy), both published as ``CheckpointSaved``;
      :meth:`recover_server` restores from the freshest *verified*
      source (``RecoveryCompleted`` records which one won).
    * ``chaos`` — a :class:`~repro.federated.chaos.FaultPlan` whose
      driver-level kinds (``disconnect``/``revocation`` severs,
      ``corrupt_checkpoint`` sabotage-then-restore) this driver
      executes, publishing a ``FaultInjected`` marker per fault.
      Client-level kinds are executed by ``ChaosClient`` wrappers in
      the worker pool (``FaultPlan.wrap_clients``).
    """

    def __init__(
        self,
        workers: WorkerPool,
        initial_params: Any,
        *,
        transport: Optional[SocketTransport] = None,
        round_deadline: Optional[RoundDeadline] = None,
        carry_discount: float = 0.5,
        escalate_after: int = 2,
        on_revocation: str = "rerequest",
        max_rerequests: int = 1,
        reply_timeout_s: Optional[float] = None,
        startup_timeout_s: float = 30.0,
        heartbeat_interval_s: Optional[float] = None,
        heartbeat_timeout_s: Optional[float] = None,
        scheduler: Optional[Any] = None,
        placement: Optional[MutableMapping[str, Any]] = None,
        server_ckpt: Optional[Any] = None,
        client_ckpts: Optional[Mapping[str, Any]] = None,
        chaos: Optional[FaultPlan] = None,
        agg_engine: Optional[AggregationEngine] = None,
        bus: Optional[EventBus] = None,
        on_straggler: Optional[Callable[[str, int], None]] = None,
        cost_model: Optional[Any] = None,
        measure_round_messages: bool = True,
        compression: Optional[Any] = None,
        schema: Optional[Any] = None,
        staleness_policy: Optional[Any] = None,
    ) -> None:
        if heartbeat_interval_s is not None and heartbeat_interval_s <= 0.0:
            raise ValueError("heartbeat_interval_s must be > 0 (or None)")
        if heartbeat_timeout_s is not None:
            if heartbeat_timeout_s <= 0.0:
                raise ValueError("heartbeat_timeout_s must be > 0 (or None)")
            if heartbeat_interval_s is None:
                raise ValueError(
                    "heartbeat_timeout_s requires heartbeat_interval_s"
                )
        self.workers = workers
        self.params = initial_params
        self.bus = bus if bus is not None else EventBus()
        self.transport = transport if transport is not None else SocketTransport()
        self.reply_timeout_s = reply_timeout_s
        self.startup_timeout_s = startup_timeout_s
        self.heartbeat_interval_s = heartbeat_interval_s
        self.heartbeat_timeout_s = (
            heartbeat_timeout_s
            if heartbeat_timeout_s is not None
            else (
                3.0 * heartbeat_interval_s
                if heartbeat_interval_s is not None
                else None
            )
        )
        self.scheduler = scheduler
        self.placement = placement
        self.server_ckpt = server_ckpt
        self.client_ckpts: Dict[str, Any] = dict(client_ckpts or {})
        self.chaos = chaos
        self.on_straggler = on_straggler
        self.cost_model = cost_model
        self.measure_round_messages = measure_round_messages
        # The workers do the encoding (the pool must be built with the
        # same spec); the driver's copy drives decode + the delta-mode
        # fold + wire-vs-dense accounting in the round message logs.
        from .agg_engine import as_update_schema
        from .compression import parse_compression
        self.compression = parse_compression(compression)
        # Structured rounds: the pool's workers ship only the schema's
        # named groups; the driver folds them through the per-group
        # masked aggregator and logs per-group wire/dense bytes.
        self.schema = as_update_schema(schema)
        self._on_revocation = on_revocation
        self._max_rerequests = max_rerequests
        self._engine = AsyncRoundEngine(
            agg_engine if agg_engine is not None else AggregationEngine(),
            on_revocation=on_revocation,
            recovery_delay_s=0.0,  # recoveries are *measured*, not modeled
            max_rerequests=max_rerequests,
            deadline=round_deadline,
            carry_discount=carry_discount,
            escalate_after=escalate_after,
            bus=self.bus,
            schema=self.schema,
            staleness_policy=staleness_policy,
        )
        self.fold_reports: List[FoldReport] = []
        self.message_logs: List[RoundMessageLog] = []
        self._cohort: List[str] = [str(c) for c in workers.client_ids]
        self._awaiting_rejoin: Set[str] = set()
        self._started = False
        self._wall_t0 = time.monotonic()

    # -- lifecycle ---------------------------------------------------------
    def __enter__(self) -> "LiveRoundDriver":
        self.start()
        return self

    def __exit__(self, *exc_info: Any) -> None:
        self.close()

    def start(self) -> None:
        """Bind the transport, launch the workers, barrier on hellos."""
        if self._started:
            return
        address = self.transport.start()
        self.workers.launch(address)
        self.transport.wait_for_clients(self._cohort, self.startup_timeout_s)
        self._wall_t0 = time.monotonic()
        self._started = True

    def close(self) -> None:
        if self._started:
            for cid in self.transport.client_ids:
                try:
                    self.transport.send(cid, {"kind": MSG_SHUTDOWN})
                except ConnectionError:
                    pass
        self.workers.shutdown()
        self.transport.close()
        self._started = False

    @property
    def trace(self) -> List[Any]:
        """The typed control-plane event trace (same vocabulary + format
        as the simulator's ``SimulationResult.trace``)."""
        return self.bus.trace

    @property
    def cohort(self) -> List[str]:
        """Silos still in the run (terminal crashes drop out)."""
        return list(self._cohort)

    def _wall(self) -> float:
        return time.monotonic() - self._wall_t0

    # -- run loop ----------------------------------------------------------
    def run(self, n_rounds: int) -> FLRunResult:
        """Drive ``n_rounds`` §3 rounds over the live workers."""
        self.start()
        t_start = time.monotonic()
        records: List[RoundRecord] = []
        for round_idx in range(1, n_rounds + 1):
            records.append(self._run_round(round_idx))
        if self.server_ckpt is not None:
            self.server_ckpt.wait_for_transfers()
        return FLRunResult(
            rounds=records,
            final_params=self.params,
            total_time_s=time.monotonic() - t_start,
        )

    # -- one round ---------------------------------------------------------
    def _run_round(self, round_idx: int) -> RoundRecord:
        self._settle_rejoins()
        # Chaos: checkpoint sabotage strikes *between* rounds (mirroring
        # FLServer's fault_hook position — marker, corruption, then the
        # §4.3 restore — all before this round's dispatch).
        restarted_from: Optional[str] = None
        if self.chaos is not None:
            for f in self.chaos.faults_for(round_idx):
                if f.kind != "corrupt_checkpoint":
                    continue
                self.bus.publish(
                    FaultInjected(
                        self._wall(), f.kind, f.task, round_idx, f.phase
                    )
                )
                corrupt_latest_checkpoint(self.server_ckpt)
                restarted_from = self.recover_server(round_idx)
        expected = [
            cid for cid in self._cohort if self.transport.is_live(cid)
        ]
        if not expected:
            raise RuntimeError("no live silos left in the cohort")
        t0 = time.monotonic()
        self.bus.publish(
            RoundDispatched(self._wall(), round_idx, len(expected))
        )
        # Chaos markers for every other kind of the round (client-side
        # kinds execute inside the workers, which have no bus — the
        # driver records the cause at the same trace position as the
        # virtual-clock ChaosSchedule).
        forced: Dict[str, str] = {}
        if self.chaos is not None:
            for f in self.chaos.faults_for(round_idx):
                if f.kind == "corrupt_checkpoint":
                    continue
                self.bus.publish(
                    FaultInjected(
                        self._wall(), f.kind, f.task, round_idx, f.phase
                    )
                )
                if f.phase == "train" and f.kind in DRIVER_KINDS:
                    forced[f.task] = f.kind

        # Training phase: s_msg_train out, c_msg_train back (measured).
        s_train_payload = serialize_pytree(self.params)
        dispatched: List[str] = []
        for cid in expected:
            try:
                self.transport.send(
                    cid,
                    {"kind": MSG_S_TRAIN, "round_idx": round_idx},
                    s_train_payload,
                )
                dispatched.append(cid)
            except ConnectionError:
                self._drop_from_cohort(cid)
        if not dispatched:
            raise RuntimeError("every silo disconnected at dispatch")

        outcomes = self._collect_train(
            round_idx, dispatched, t0, s_train_payload, forced
        )

        t_agg = time.monotonic()
        results = [
            ClientResult(cid, o.params, o.n_samples, o.train_time_s)
            for cid, o in outcomes.items()
        ]
        schedule = RecordedSchedule(
            {cid: o.to_arrival(cid) for cid, o in outcomes.items()}
        )
        fold = self._engine.fold_round(
            round_idx, results, schedule,
            base_params=(
                self.params
                if (self.compression is not None or self.schema is not None)
                else None
            ),
        )
        self.fold_reports.append(fold)
        self.params = fold.params
        jax.block_until_ready(self.params)
        agg_time = time.monotonic() - t_agg
        train_time = time.monotonic() - t0

        # §4.4: consecutive reply timeouts escalate like deadline misses
        # (the engine handles carried-over silos itself; timeouts are
        # excluded from the fold, so the driver advances the tracker).
        # An on-time delivery clears the silo's streak — the engine only
        # does that when a RoundDeadline is configured — and so does a
        # crash: replacing the worker destroys the slow-silo evidence
        # (the StragglerTracker contract), so a recovery that overran
        # the reply window must not count as a strike.
        for cid, o in outcomes.items():
            if o.timed_out:
                streak = self._engine.stragglers.record_miss(cid)
                if streak is not None:
                    self.bus.publish(
                        StragglerEscalated(
                            o.revoke_at_s or 0.0,
                            cid,
                            round_idx=round_idx,
                            consecutive_misses=streak,
                        )
                    )
                    if self.on_straggler is not None:
                        self.on_straggler(cid, round_idx)
            elif o.crashed or (
                not o.failed and cid not in fold.carried_over
            ):
                # Carried-over silos keep the miss the engine recorded;
                # everyone else's evidence resets.
                self._engine.stragglers.clear(cid)
        for cid in fold.escalations:
            if self.on_straggler is not None:
                self.on_straggler(cid, round_idx)

        # Evaluation phase: s_msg_aggreg out, c_msg_test back.
        t1 = time.monotonic()
        s_aggreg_payload = serialize_pytree(self.params)
        eval_targets: List[str] = []
        for cid in self._cohort:
            if not self.transport.is_live(cid):
                continue
            try:
                self.transport.send(
                    cid,
                    {"kind": MSG_S_AGGREG, "round_idx": round_idx},
                    s_aggreg_payload,
                )
                eval_targets.append(cid)
            except ConnectionError:
                self._drop_from_cohort(cid)
        # Chaos: driver-level eval-phase faults sever now — the silo
        # skips this round's metrics only; the stray-disconnect path
        # restarts it (cross-host when a scheduler is attached) so it
        # rejoins for the next round.
        if self.chaos is not None:
            for f in self.chaos.faults_for(round_idx, phase="eval"):
                if (
                    f.kind in DRIVER_KINDS
                    and f.task in eval_targets
                    and self.transport.disconnect(f.task)
                ):
                    eval_targets.remove(f.task)
                    self._handle_stray_disconnect(f.task)
        metrics_by_cid, eval_n, c_test_bytes = self._collect_eval(
            round_idx, eval_targets, t1
        )
        if metrics_by_cid:
            order = sorted(metrics_by_cid)
            metrics = aggregate_metrics(
                [metrics_by_cid[cid] for cid in order],
                [max(eval_n.get(cid, 1), 1) for cid in order],
            )
        else:
            metrics = {}
        eval_time = time.monotonic() - t1

        # Checkpointing (§4.3), mirroring FLServer: every surviving silo
        # stores the aggregate each round, the server per its interval,
        # each location's overhead published separately.
        t2 = time.monotonic()
        saved_client = False
        for cid in self._cohort:
            mgr = self.client_ckpts.get(cid)
            if mgr is not None:
                mgr.save(round_idx, self.params)
                saved_client = True
        client_ckpt_time = time.monotonic() - t2
        t3 = time.monotonic()
        saved_server = (
            self.server_ckpt is not None
            and self.server_ckpt.should_checkpoint(round_idx)
        )
        if saved_server and self.server_ckpt is not None:
            self.server_ckpt.save(round_idx, self.params)
        server_ckpt_time = time.monotonic() - t3
        ckpt_time = client_ckpt_time + server_ckpt_time
        if saved_client:
            self.bus.publish(
                CheckpointSaved(self._wall(), round_idx, "client_local",
                                client_ckpt_time)
            )
        if saved_server:
            self.bus.publish(
                CheckpointSaved(self._wall(), round_idx, "server_remote",
                                server_ckpt_time)
            )

        log: Optional[RoundMessageLog] = None
        if self.measure_round_messages:
            c_train_bytes = max(
                (o.payload_bytes for o in outcomes.values()
                 if o.payload_bytes > 0),
                default=len(s_train_payload),
            )
            # With compression, payload_bytes is the measured compressed
            # frame (what crossed the socket) — the wire truth Eq. 6
            # needs; the workers' reported dense-equivalent size rides
            # along so the log can state the achieved ratio.
            dense_train = max(
                (o.dense_bytes for o in outcomes.values()
                 if o.dense_bytes > 0),
                default=0,
            )
            # Per-group byte maps (structured rounds): merged over the
            # round's replies by max, like the scalar fields — the log
            # records a representative (worst-case) per-silo frame.
            group_wire: Optional[Dict[str, int]] = None
            group_dense: Optional[Dict[str, int]] = None
            for o in outcomes.values():
                if o.group_bytes:
                    group_wire = group_wire or {}
                    for k, v in o.group_bytes.items():
                        group_wire[k] = max(group_wire.get(k, 0), int(v))
                if o.group_dense:
                    group_dense = group_dense or {}
                    for k, v in o.group_dense.items():
                        group_dense[k] = max(group_dense.get(k, 0), int(v))
            if self.schema is not None:
                codec = ("structured" if self.compression is None
                         else f"structured:{self.compression.codec}")
            elif self.compression is not None:
                codec = self.compression.codec
            else:
                codec = "none"
            log = RoundMessageLog(
                s_msg_train_bytes=len(s_train_payload),
                c_msg_train_bytes=c_train_bytes,
                s_msg_aggreg_bytes=len(s_aggreg_payload),
                c_msg_test_bytes=max(
                    c_test_bytes, default=len(serialize_metrics(metrics))
                ),
                codec=codec,
                c_msg_train_dense_bytes=dense_train or None,
                group_wire_bytes=group_wire,
                group_dense_bytes=group_dense,
            )
            self.message_logs.append(log)
            if self.cost_model is not None:
                # Eq. 6 on measured payloads: the scheduler's comm-cost
                # terms track what this run actually moved on the wire.
                self.cost_model.update_message_sizes(to_cost_model_sizes(log))

        return RoundRecord(
            round_idx=round_idx,
            train_time_s=train_time,
            eval_time_s=eval_time,
            checkpoint_time_s=ckpt_time,
            metrics=metrics,
            message_log=log,
            restarted_from=restarted_from,
            agg_time_s=agg_time,
            fold_times_s=dict(fold.fold_times),
            round_span_s=fold.round_span_s,
            idle_s=fold.idle_s,
            deadline_s=fold.deadline_s,
            carried_over=list(fold.carried_over),
            carried_in=list(fold.carried_in),
        )

    # -- §4.3 / §4.4 recovery ----------------------------------------------
    def _restart_worker(self, client_id: str) -> bool:
        """Respawn a dead silo's worker — on a *different* host when a
        scheduler is attached (§4.4 true replacement).

        ``DynamicScheduler.select_instance`` excludes the revoked VM
        from its candidate set, so the pick is a genuine move; the
        mutable ``placement`` map is updated and ``VMReplaced`` is
        published only once the pool actually spawned the replacement.
        Without a scheduler (or for silos outside the placement map) the
        restart rejoins in place, exactly as before."""
        decision: Optional[Any] = None
        old_vm = ""
        if (
            self.scheduler is not None
            and self.placement is not None
            and client_id in self.placement
        ):
            old_vm = str(self.placement[client_id].vm_id)
            decision = self.scheduler.select_instance(
                client_id, dict(self.placement), old_vm, now_s=self._wall()
            )
            if decision is not None and not getattr(decision, "new_vm", None):
                decision = None
        host = None if decision is None else str(decision.new_vm)
        ok = self.workers.restart(client_id, self.transport.address, host=host)
        if ok and decision is not None and self.placement is not None:
            market = str(getattr(decision, "market", "on_demand"))
            self.placement[client_id] = Assignment(
                str(decision.new_vm), market
            )
            self.bus.publish(
                VMReplaced(
                    self._wall(),
                    client_id,
                    old_vm,
                    str(decision.new_vm),
                    market,
                    "revocation",
                )
            )
        return ok

    def recover_server(self, resume_round: int) -> str:
        """Restore the aggregate from the freshest *verified* checkpoint
        (§4.3), mirroring ``FLServer._recover_server``: corrupt or
        truncated files are skipped by the managers' verified-restore
        path, so sabotage falls back to the newest intact source.
        Publishes ``RecoveryCompleted`` recording which source won."""
        if self.server_ckpt is None and not self.client_ckpts:
            source, info = "none", None
        else:
            source, info = resolve_freshest(self.server_ckpt, self.client_ckpts)
        if source == "none" or info is None:
            self.bus.publish(
                RecoveryCompleted(self._wall(), "s", resume_round, 0.0, "none")
            )
            return "none"
        if source == "server":
            assert self.server_ckpt is not None
            _, self.params = self.server_ckpt.restore(self.params, info)
        else:
            cid = source.split(":", 1)[1]
            _, self.params = self.client_ckpts[cid].restore(self.params)
        restored = (
            "server_remote" if source == "server"
            else f"client_local:{source.split(':', 1)[1]}"
        )
        self.bus.publish(
            RecoveryCompleted(self._wall(), "s", resume_round, 0.0, restored)
        )
        return source

    # -- collection loops --------------------------------------------------
    def _drop_from_cohort(self, client_id: str) -> None:
        if client_id in self._cohort:
            self._cohort.remove(client_id)

    def _handle_stray_disconnect(self, client_id: str) -> None:
        """A silo crashed *outside* its training reply (after delivering,
        or during the evaluation phase).  The round is unaffected — the
        already-delivered rule — but §4.3 still owes the silo a
        replacement: restart the worker so it rejoins for the next
        round (it merely skips this round's metrics); only when no
        replacement can be spawned does the silo leave the run."""
        if self._on_revocation == "rerequest" and self._restart_worker(
            client_id
        ):
            self._awaiting_rejoin.add(client_id)
            return
        self._drop_from_cohort(client_id)

    def _settle_rejoins(self) -> None:
        """Barrier on restarted workers' hellos before dispatching a
        round, so a silo replaced between rounds (eval-phase crash) is
        back in the cohort and not skipped by a hello/dispatch race.
        A replacement that never connects within the startup window is
        dropped from the run."""
        self._awaiting_rejoin = {
            cid for cid in self._awaiting_rejoin
            if cid in self._cohort and not self.transport.is_live(cid)
        }
        deadline = time.monotonic() + self.startup_timeout_s
        while self._awaiting_rejoin and time.monotonic() < deadline:
            self.transport.poll(0.05)
            self._awaiting_rejoin = {
                cid for cid in self._awaiting_rejoin
                if not self.transport.is_live(cid)
            }
        for cid in sorted(self._awaiting_rejoin):
            self._drop_from_cohort(cid)
        self._awaiting_rejoin.clear()

    def _collect_train(
        self,
        round_idx: int,
        expected: Sequence[str],
        t0: float,
        s_train_payload: bytes,
        forced: Optional[Mapping[str, str]] = None,
    ) -> Dict[str, _TrainOutcome]:
        outcomes: Dict[str, _TrainOutcome] = {
            cid: _TrainOutcome() for cid in expected
        }
        pending: Set[str] = set(expected)
        rejoining: Set[str] = set()
        rejoin_by: Dict[str, float] = {}  # restart -> hello deadline (wall)
        deadline = (
            None if self.reply_timeout_s is None
            else t0 + self.reply_timeout_s
        )
        # Liveness probing state (heartbeat_interval_s only).
        hb = self.heartbeat_interval_s
        hb_timeout = self.heartbeat_timeout_s
        last_seen: Dict[str, float] = {cid: t0 for cid in expected}
        next_ping = None if hb is None else t0 + hb
        ping_seq = 0

        def crash(cid: str, now_off: float) -> None:
            """The §4.3 hard-fault path: re-request via a (possibly
            cross-host) worker restart, or exclude + drop."""
            o = outcomes[cid]
            o.crashed = True
            if o.revoke_at_s is None:
                o.revoke_at_s = now_off
            if (
                self._on_revocation == "rerequest"
                and o.attempt <= self._max_rerequests
                and self._restart_worker(cid)
            ):
                rejoining.add(cid)
                rejoin_by[cid] = time.monotonic() + self.startup_timeout_s
            else:
                o.failed = True
                pending.discard(cid)
                self._drop_from_cohort(cid)

        # Chaos: driver-level train-phase faults sever right after
        # dispatch — the worker dies on EOF (a mid-compute silo fails on
        # its reply send), and recovery runs the ordinary crash path.
        for cid in sorted(forced or ()):
            if cid in pending and self.transport.disconnect(cid):
                crash(cid, time.monotonic() - t0)

        while pending:
            now = time.monotonic()
            waits: List[float] = []
            if deadline is not None:
                waits.append(deadline - now)
            if rejoin_by:
                # A restarted worker that never says hello (child died
                # before connecting, connect refused) must not hang an
                # unbounded round: bound the wait on its rejoin too.
                waits.append(min(rejoin_by.values()) - now)
            if next_ping is not None:
                waits.append(next_ping - now)
                if hb_timeout is not None:
                    expiries = [
                        last_seen[c] + hb_timeout - now
                        for c in pending
                        if c not in rejoining
                    ]
                    if expiries:
                        waits.append(min(expiries))
            timeout = max(0.0, min(waits)) if waits else None
            events = self.transport.poll(timeout)
            now = time.monotonic()
            now_off = now - t0
            for cid in [c for c, t in rejoin_by.items() if now >= t]:
                # Replacement never came up: §4.3 exclusion, and the
                # silo leaves the run (its worker is gone for good).
                del rejoin_by[cid]
                rejoining.discard(cid)
                outcomes[cid].failed = True
                pending.discard(cid)
                self._drop_from_cohort(cid)
            if next_ping is not None and hb is not None and now >= next_ping:
                next_ping = now + hb
                ping_seq += 1
                for cid in sorted(pending - rejoining):
                    if not self.transport.is_live(cid):
                        continue
                    try:
                        self.transport.send(
                            cid, {"kind": MSG_PING, "seq": ping_seq}
                        )
                    except ConnectionError:
                        crash(cid, now_off)
                if hb_timeout is not None:
                    # No PONG within the timeout = *hung*, not slow (a
                    # slow silo's receive loop still answers probes):
                    # sever and run the §4.3 crash path.  A silo with
                    # traffic in this very poll batch is alive — skip it.
                    seen_now = {ev.client_id for ev in events}
                    for cid in sorted(pending - rejoining - seen_now):
                        if now - last_seen.get(cid, t0) > hb_timeout:
                            self.transport.disconnect(cid)
                            crash(cid, now_off)
            if not events:
                if deadline is not None and now >= deadline:
                    # Reply timeout.  A silent-but-alive silo is a §4.4
                    # straggler suspect: it stays in the cohort, its
                    # stale reply is discarded by round tag, and its
                    # miss streak advances.  A silo whose *recovery* is
                    # what overran the window crashed — the replacement
                    # destroyed the slow-silo evidence, so it is only
                    # excluded (§4.3), never counted as a strike.
                    for cid in sorted(pending):
                        o = outcomes[cid]
                        o.failed = True
                        o.timed_out = not o.crashed
                        if o.revoke_at_s is None:
                            o.revoke_at_s = now_off
                    pending.clear()
                continue
            for ev in events:
                cid = ev.client_id
                if cid in last_seen:
                    last_seen[cid] = now
                if ev.kind == "disconnect":
                    if cid not in pending:
                        self._handle_stray_disconnect(cid)
                        continue
                    crash(cid, now_off)
                elif ev.kind == "joined":
                    if cid in rejoining:
                        rejoining.discard(cid)
                        rejoin_by.pop(cid, None)
                        o = outcomes[cid]
                        o.attempt += 1
                        try:
                            self.transport.send(
                                cid,
                                {"kind": MSG_S_TRAIN, "round_idx": round_idx},
                                s_train_payload,
                            )
                        except ConnectionError:
                            o.failed = True
                            pending.discard(cid)
                            self._drop_from_cohort(cid)
                elif (
                    ev.kind == "message"
                    and ev.header.get("kind") == MSG_C_TRAIN
                ):
                    if (
                        int(ev.header.get("round_idx", -1)) != round_idx
                        or cid not in pending
                    ):
                        continue  # stale reply from a previous round
                    o = outcomes[cid]
                    try:
                        # Compressed replies carry their codec in the
                        # header; a frame corrupted in either encoding
                        # raises the same DeserializationError, so the
                        # §4.3 re-request recovery below is shared.
                        if ev.header.get("structured"):
                            from .compression import deserialize_structured

                            params = deserialize_structured(ev.payload)
                        elif ev.header.get("codec") is not None:
                            from .compression import deserialize_update

                            params = deserialize_update(ev.payload)
                        else:
                            params = deserialize_pytree(ev.payload, self.params)
                    except DeserializationError:
                        # Corrupt frame: the reply arrived but is
                        # unusable — a §4.3 suspected fault.  The worker
                        # is alive, so re-request over the *same*
                        # connection (attempt bump mirrors the crash
                        # path); past the budget the silo is excluded
                        # from the round but stays in the cohort.
                        if o.revoke_at_s is None:
                            o.revoke_at_s = now_off
                        if (
                            self._on_revocation == "rerequest"
                            and o.attempt <= self._max_rerequests
                            and self.transport.is_live(cid)
                        ):
                            o.attempt += 1
                            try:
                                self.transport.send(
                                    cid,
                                    {
                                        "kind": MSG_S_TRAIN,
                                        "round_idx": round_idx,
                                    },
                                    s_train_payload,
                                )
                            except ConnectionError:
                                crash(cid, now_off)
                        else:
                            o.failed = True
                            pending.discard(cid)
                        continue
                    o.arrival_s = now_off
                    o.params = params
                    o.n_samples = int(ev.header.get("n_samples", 0))
                    o.train_time_s = float(ev.header.get("train_time_s", 0.0))
                    o.payload_bytes = len(ev.payload)
                    o.dense_bytes = int(ev.header.get("dense_bytes", 0))
                    gb = ev.header.get("group_bytes")
                    if isinstance(gb, Mapping):
                        o.group_bytes = {str(k): int(v) for k, v in gb.items()}
                    gd = ev.header.get("group_dense")
                    if isinstance(gd, Mapping):
                        o.group_dense = {str(k): int(v) for k, v in gd.items()}
                    pending.discard(cid)
        return outcomes

    def _collect_eval(
        self,
        round_idx: int,
        expected: Sequence[str],
        t1: float,
    ) -> Tuple[Dict[str, Dict[str, float]], Dict[str, int], List[int]]:
        metrics_by_cid: Dict[str, Dict[str, float]] = {}
        eval_n: Dict[str, int] = {}
        sizes: List[int] = []
        pending: Set[str] = set(expected)
        deadline = (
            None if self.reply_timeout_s is None
            else t1 + self.reply_timeout_s
        )
        while pending:
            timeout: Optional[float] = None
            if deadline is not None:
                timeout = max(0.0, deadline - time.monotonic())
            events = self.transport.poll(timeout)
            if not events:
                if deadline is not None and time.monotonic() >= deadline:
                    break  # slow evaluators are skipped, not faulted
                continue
            for ev in events:
                cid = ev.client_id
                if ev.kind == "disconnect":
                    # Evaluation-phase crash: this round just skips the
                    # silo's metrics; §4.3 still restarts the worker so
                    # it rejoins for the next round.
                    pending.discard(cid)
                    self._handle_stray_disconnect(cid)
                elif (
                    ev.kind == "message"
                    and ev.header.get("kind") == MSG_C_TEST
                ):
                    if (
                        int(ev.header.get("round_idx", -1)) != round_idx
                        or cid not in pending
                    ):
                        continue
                    raw = msgpack.unpackb(ev.payload, raw=False)
                    metrics_by_cid[cid] = {
                        str(k): float(v) for k, v in dict(raw).items()
                    }
                    eval_n[cid] = int(ev.header.get("n_samples", 0))
                    sizes.append(len(ev.payload))
                    pending.discard(cid)
        return metrics_by_cid, eval_n, sizes
