"""Async round engine: straggler-folding FL rounds on the StreamingAggregator.

The paper's §3 protocol barriers every round on the slowest silo: the
server collects all N ``c_msg_train`` messages, then aggregates.  In
multi-cloud runs (§4.3/§5) stragglers and preemptible-VM revocations
dominate round time, so the barrier leaves the server idle exactly when
it has work available.  This module replaces the barrier with an
event-driven fold: each ``c_msg_train`` is folded into a
:class:`~repro.federated.agg_engine.StreamingAggregator` the moment it
arrives (O(L) accumulator memory, never an (N, L) gather), and the round
barriers only on the *round count* — every silo's update is still in the
round's average, preserving the paper's cross-silo "wait for all
clients" semantics; only the server's idle time is folded away.

Arrival-schedule abstraction
----------------------------
Message arrival is decoupled from message *content* so the same engine
serves both the simulator and real ``FLClient`` processes.  An
:class:`ArrivalSchedule` maps ``(round_idx, client_ids)`` to per-client
:class:`ClientArrival` events on a virtual clock that starts at the
round's ``s_msg_train`` dispatch:

* ``delay_s``      — virtual seconds until the client's ``c_msg_train``
  lands on the server (local train + cross-cloud transfer);
* ``revoke_at_s``  — optional virtual time the silo's spot VM is
  revoked.  A revocation *before* delivery loses the update; one after
  delivery is harmless for this round (the simulator's "already
  delivered" rule).

Schedules provided: :class:`InstantSchedule` (every message present at
dispatch — the degenerate case that makes the barrier ``FLServer`` a
special case of this engine), :class:`DeterministicSchedule` (fixed
per-client delays and revocation times, for tests),
:class:`HeavyTailSchedule` (lognormal delays with designated or random
stragglers), and :class:`RevocationInjector` (decorates any schedule
with Poisson spot revocations reusing :mod:`repro.core.revocation`).

Revocation handling follows §4.3: by default the engine *re-requests*
the lost update (the replacement VM retrains and its message arrives
after the recovery delay — the server never silently drops a silo);
``on_revocation="exclude"`` instead drops the silo from the current
round only, for deadline-bound deployments.

The fold loop advances a virtual clock but charges each fold with the
*measured* wall-clock cost of the real ``StreamingAggregator.add``, so
reports mix simulated arrival latency with real aggregation compute.
Per-client fold completion times are threaded into
:class:`~repro.federated.server.RoundRecord` and (via
``CostModel.t_fold`` / ``async_round_time``) into the simulator's
round-time accounting.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Any, Dict, List, Mapping, Optional, Sequence, Union

import jax

from repro.core.revocation import RevocationModel, RevocationSampler
from .agg_engine import AggregationEngine
from .client import ClientResult

__all__ = [
    "ArrivalSchedule",
    "AsyncFLServer",
    "AsyncRoundEngine",
    "ClientArrival",
    "DeterministicSchedule",
    "FoldEvent",
    "FoldReport",
    "HeavyTailSchedule",
    "InstantSchedule",
    "RevocationInjector",
]


# ---------------------------------------------------------------------------
# Arrival model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ClientArrival:
    """One client's ``c_msg_train`` arrival event on the round's virtual clock."""

    client_id: str
    delay_s: float                      # dispatch -> message-on-server
    revoke_at_s: Optional[float] = None  # spot VM revoked at this time (None = survives)

    def delivered_before_revocation(self) -> bool:
        return self.revoke_at_s is None or self.revoke_at_s > self.delay_s


class ArrivalSchedule:
    """Maps a round to per-client arrival events (virtual seconds)."""

    def round_arrivals(
        self, round_idx: int, client_ids: Sequence[str]
    ) -> Dict[str, ClientArrival]:
        raise NotImplementedError


class InstantSchedule(ArrivalSchedule):
    """Every message is present at dispatch: the barrier server's timeline.

    With this schedule the async engine degenerates to one fused batch
    reduce (all inputs available at t=0), which is exactly the sync
    ``FLServer`` hot path."""

    def round_arrivals(self, round_idx, client_ids):
        return {cid: ClientArrival(cid, 0.0) for cid in client_ids}


class DeterministicSchedule(ArrivalSchedule):
    """Fixed delays (scalar or per-client) and optional revocation times."""

    def __init__(
        self,
        delays: Union[float, Mapping[str, float]],
        revoke_at: Optional[Mapping[str, float]] = None,
    ) -> None:
        self.delays = delays
        self.revoke_at = dict(revoke_at or {})

    def round_arrivals(self, round_idx, client_ids):
        out = {}
        for cid in client_ids:
            d = self.delays if isinstance(self.delays, (int, float)) else self.delays[cid]
            out[cid] = ClientArrival(cid, float(d), self.revoke_at.get(cid))
        return out


class HeavyTailSchedule(ArrivalSchedule):
    """Lognormal arrival delays with heavy-tail stragglers.

    Each client's delay is ``base_s * lognormal(0, sigma)``; clients in
    ``straggler_ids`` (or hit by ``straggler_prob`` each round) are
    multiplied by ``straggler_factor`` — the 1-slow-silo-in-8 shape the
    paper's multi-cloud traces show."""

    def __init__(
        self,
        base_s: float = 1.0,
        sigma: float = 0.25,
        straggler_ids: Sequence[str] = (),
        straggler_factor: float = 5.0,
        straggler_prob: float = 0.0,
        seed: int = 0,
    ) -> None:
        import numpy as np

        self.base_s = base_s
        self.sigma = sigma
        self.straggler_ids = frozenset(straggler_ids)
        self.straggler_factor = straggler_factor
        self.straggler_prob = straggler_prob
        self._rng = np.random.default_rng(seed)

    def round_arrivals(self, round_idx, client_ids):
        out = {}
        for cid in client_ids:
            d = self.base_s * float(self._rng.lognormal(0.0, self.sigma))
            if cid in self.straggler_ids or (
                self.straggler_prob > 0.0
                and self._rng.uniform() < self.straggler_prob
            ):
                d *= self.straggler_factor
            out[cid] = ClientArrival(cid, d)
        return out


class RevocationInjector(ArrivalSchedule):
    """Decorate any schedule with Poisson spot revocations (§5.6 model).

    Events are drawn from the *global* Poisson process of
    :class:`repro.core.revocation.RevocationModel` against a running
    cross-round clock; each event landing inside a round's horizon
    revokes one uniformly-chosen still-running spot client (a client
    whose message has not yet arrived).  Events with no live spot
    victim are absorbed, matching the revocation module's semantics."""

    def __init__(
        self,
        inner: ArrivalSchedule,
        model: RevocationModel,
        spot_clients: Optional[Sequence[str]] = None,
        horizon_s: Optional[float] = None,
    ) -> None:
        self.inner = inner
        self.spot_clients = None if spot_clients is None else frozenset(spot_clients)
        self.horizon_s = horizon_s
        self._sampler: RevocationSampler = model.sampler()
        self._clock = 0.0
        self._next_event = self._sampler.next_event_after(0.0)

    def round_arrivals(self, round_idx, client_ids):
        arrivals = dict(self.inner.round_arrivals(round_idx, client_ids))
        horizon = self.horizon_s
        if horizon is None:
            horizon = max((a.delay_s for a in arrivals.values()), default=0.0)
        round_end = self._clock + horizon

        while self._next_event <= round_end:
            t = self._next_event - self._clock  # round-local virtual time
            self._next_event = self._sampler.next_event_after(self._next_event)
            live_spot = sorted(
                cid
                for cid, a in arrivals.items()
                if a.delay_s > t
                and a.revoke_at_s is None
                and (self.spot_clients is None or cid in self.spot_clients)
            )
            victim = self._sampler.pick_victim(live_spot)
            if victim is None:
                continue
            a = arrivals[victim]
            arrivals[victim] = dataclasses.replace(a, revoke_at_s=t)
        self._clock = round_end
        return arrivals


# ---------------------------------------------------------------------------
# Fold engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FoldEvent:
    """One client fold on the round's virtual clock."""

    client_id: str
    arrival_s: float       # when its c_msg_train became foldable
    fold_start_s: float    # server picked it up (>= arrival; folds serialize)
    fold_end_s: float
    attempt: int = 1       # >1 after a revocation re-request
    revoked_at_s: Optional[float] = None


@dataclasses.dataclass
class FoldReport:
    """Result of one async round fold."""

    params: Any
    events: List[FoldEvent]
    excluded: List[str]           # silos dropped this round (exclude policy)
    rerequested: List[str]        # silos whose update was re-requested
    fold_times: Dict[str, float]  # client_id -> virtual fold-completion time
    round_span_s: float           # dispatch -> aggregated params ready
    busy_s: float                 # server time spent folding
    idle_s: float                 # round_span_s - busy_s (waiting on arrivals)
    # Counterfactual: wait for the last arrival, then do the SAME fold
    # work (last_arrival + busy_s).  With measured fold costs this is an
    # upper bound on the real sync FLServer's span — the barrier path
    # runs the fused batch reduce, which beats N incremental folds; see
    # benchmarks/async_round_bench.py for the measured-batch comparison.
    barrier_span_s: float

    @property
    def span_saved_s(self) -> float:
        """Round time the streaming fold hides vs. barriering on the last
        arrival and then doing the same fold work (see barrier_span_s for
        why this bounds, rather than equals, the sync-server saving)."""
        return self.barrier_span_s - self.round_span_s


class AsyncRoundEngine:
    """Folds one round's client results in arrival order.

    Parameters
    ----------
    agg_engine : the fused :class:`AggregationEngine` (stats and the
        degenerate batch path route through it).
    on_revocation : §4.3 recovery rule for an update lost to revocation:
        ``"rerequest"`` (default — the replacement VM retrains, arriving
        ``recovery_delay_s + delay`` after the revocation, so the silo is
        still in the round's average) or ``"exclude"`` (drop the silo
        from this round only).
    recovery_delay_s : virtual VM replacement + restore time charged
        before a re-requested client restarts training.
    max_rerequests : re-request budget per client per round; a client
        revoked more than this many times is excluded.
    fold_cost_s : override the virtual cost of each fold (deterministic
        tests / simulators); None charges the measured wall-clock cost
        of the real ``StreamingAggregator.add``.
    """

    def __init__(
        self,
        agg_engine: Optional[AggregationEngine] = None,
        on_revocation: str = "rerequest",
        recovery_delay_s: float = 0.0,
        max_rerequests: int = 1,
        fold_cost_s: Optional[float] = None,
    ) -> None:
        if on_revocation not in ("rerequest", "exclude"):
            raise ValueError("on_revocation must be 'rerequest' or 'exclude'")
        self.agg_engine = agg_engine if agg_engine is not None else AggregationEngine()
        self.on_revocation = on_revocation
        self.recovery_delay_s = recovery_delay_s
        self.max_rerequests = max_rerequests
        self.fold_cost_s = fold_cost_s

    # ------------------------------------------------------------------
    def fold_round(
        self,
        round_idx: int,
        results: Sequence[ClientResult],
        schedule: ArrivalSchedule,
    ) -> FoldReport:
        """Fold all of a round's ``c_msg_train`` messages per the schedule."""
        if not results:
            raise ValueError("fold_round needs at least one client result")
        by_id = {r.client_id: r for r in results}
        arrivals = schedule.round_arrivals(round_idx, list(by_id))

        if all(
            a.delay_s == 0.0 and a.revoke_at_s is None for a in arrivals.values()
        ):
            return self._fold_degenerate(results)

        # Event heap: (effective arrival, seq, client_id, attempt, revoke_at).
        heap: List[Any] = []
        for seq, (cid, a) in enumerate(arrivals.items()):
            heapq.heappush(heap, (a.delay_s, seq, cid, 1, a.revoke_at_s))
        seq = len(heap)

        agg = self.agg_engine.streaming()
        events: List[FoldEvent] = []
        excluded: List[str] = []
        rerequested: List[str] = []
        server_free = 0.0
        busy = 0.0

        while heap:
            arrival, _, cid, attempt, revoke_at = heapq.heappop(heap)
            if revoke_at is not None and revoke_at <= arrival:
                # The silo died before its message landed: §4.3 recovery.
                if self.on_revocation == "rerequest" and attempt <= self.max_rerequests:
                    retrain = arrivals[cid].delay_s
                    re_arrival = revoke_at + self.recovery_delay_s + retrain
                    heapq.heappush(heap, (re_arrival, seq, cid, attempt + 1, None))
                    seq += 1
                    rerequested.append(cid)
                else:
                    excluded.append(cid)
                continue

            res = by_id[cid]
            t0 = time.monotonic()
            agg.add(res.params, res.n_samples, block=True)
            measured = time.monotonic() - t0
            cost = self.fold_cost_s if self.fold_cost_s is not None else measured
            start = max(arrival, server_free)
            end = start + cost
            server_free = end
            busy += cost
            events.append(
                FoldEvent(cid, arrival, start, end, attempt=attempt,
                          revoked_at_s=revoke_at)
            )

        if not events:
            raise ValueError(
                "every silo's update was revoked and excluded; nothing to fold"
            )

        t0 = time.monotonic()
        params = agg.result()
        jax.block_until_ready(params)
        finalize = (time.monotonic() - t0) if self.fold_cost_s is None else 0.0
        busy += finalize
        span = server_free + finalize
        last_arrival = max(e.arrival_s for e in events)
        return FoldReport(
            params=params,
            events=events,
            excluded=excluded,
            rerequested=rerequested,
            fold_times={e.client_id: e.fold_end_s for e in events},
            round_span_s=span,
            busy_s=busy,
            idle_s=max(0.0, span - busy),
            # A barrier server waits for the last arrival, then does the
            # same total aggregation work in one go.
            barrier_span_s=last_arrival + busy,
        )

    # ------------------------------------------------------------------
    def _fold_degenerate(self, results: Sequence[ClientResult]) -> FoldReport:
        """All messages present at dispatch: one fused batch reduce.

        This is the sync ``FLServer`` path — the barrier protocol is the
        degenerate schedule of this engine, and it keeps the fused
        flatten-once/Pallas reduce (better than N streaming folds when
        every input is already in memory)."""
        t0 = time.monotonic()
        params = self.agg_engine.aggregate(
            [r.params for r in results], [r.n_samples for r in results]
        )
        jax.block_until_ready(params)
        agg_s = time.monotonic() - t0
        events = [
            FoldEvent(r.client_id, 0.0, 0.0, agg_s) for r in results
        ]
        return FoldReport(
            params=params,
            events=events,
            excluded=[],
            rerequested=[],
            fold_times={r.client_id: agg_s for r in results},
            round_span_s=agg_s,
            busy_s=agg_s,
            idle_s=0.0,
            barrier_span_s=agg_s,
        )


# ---------------------------------------------------------------------------
# Async server
# ---------------------------------------------------------------------------

# Imported late: server.py's sync path lazily imports this module, so a
# top-level `from .server import FLServer` here completes the cycle only
# after server.py has fully loaded.
from .server import FLServer  # noqa: E402


class AsyncFLServer(FLServer):
    """FLServer whose rounds fold ``c_msg_train`` messages as they land.

    Identical protocol and results to :class:`FLServer` (same training,
    evaluation, checkpointing, and fault-hook semantics) but the
    aggregation phase runs through :class:`AsyncRoundEngine` with a
    pluggable :class:`ArrivalSchedule`, so round records carry per-client
    fold timestamps, the server's busy/idle split, and the counterfactual
    barrier span."""

    def __init__(
        self,
        clients,
        initial_params,
        schedule: Optional[ArrivalSchedule] = None,
        on_revocation: str = "rerequest",
        recovery_delay_s: float = 0.0,
        max_rerequests: int = 1,
        fold_cost_s: Optional[float] = None,
        **kwargs,
    ) -> None:
        super().__init__(clients, initial_params, **kwargs)
        self.schedule = schedule if schedule is not None else InstantSchedule()
        self._round_engine = AsyncRoundEngine(
            self.agg_engine,
            on_revocation=on_revocation,
            recovery_delay_s=recovery_delay_s,
            max_rerequests=max_rerequests,
            fold_cost_s=fold_cost_s,
        )
        self.fold_reports: List[FoldReport] = []

    def _fold_phase(self, round_idx: int, results: Sequence[ClientResult]) -> FoldReport:
        report = self._round_engine.fold_round(round_idx, results, self.schedule)
        self.fold_reports.append(report)
        return report
