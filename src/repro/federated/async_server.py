"""Async round engine: straggler-folding FL rounds on the StreamingAggregator.

The paper's §3 protocol barriers every round on the slowest silo: the
server collects all N ``c_msg_train`` messages, then aggregates.  In
multi-cloud runs (§4.3/§5) stragglers and preemptible-VM revocations
dominate round time, so the barrier leaves the server idle exactly when
it has work available.  This module replaces the barrier with an
event-driven fold: each ``c_msg_train`` is folded into a
:class:`~repro.federated.agg_engine.StreamingAggregator` the moment it
arrives (O(L) accumulator memory, never an (N, L) gather), and the round
barriers only on the *round count* — every silo's update is still in the
round's average, preserving the paper's cross-silo "wait for all
clients" semantics; only the server's idle time is folded away.

Arrival-schedule abstraction
----------------------------
Message arrival is decoupled from message *content* so the same engine
serves both the simulator and real ``FLClient`` processes.  An
:class:`ArrivalSchedule` maps ``(round_idx, client_ids)`` to per-client
:class:`ClientArrival` events on a virtual clock that starts at the
round's ``s_msg_train`` dispatch:

* ``delay_s``      — virtual seconds until the client's ``c_msg_train``
  lands on the server (local train + cross-cloud transfer);
* ``revoke_at_s``  — optional virtual time the silo's spot VM is
  revoked.  A revocation *before* delivery loses the update; one after
  delivery is harmless for this round (the simulator's "already
  delivered" rule).

Schedules provided: :class:`InstantSchedule` (every message present at
dispatch — the degenerate case that makes the barrier ``FLServer`` a
special case of this engine), :class:`DeterministicSchedule` (fixed
per-client delays and revocation times, for tests),
:class:`HeavyTailSchedule` (lognormal delays with designated or random
stragglers), and :class:`RevocationInjector` (decorates any schedule
with Poisson spot revocations reusing :mod:`repro.core.revocation`).

Revocation handling follows §4.3: by default the engine *re-requests*
the lost update (the replacement VM retrains and its message arrives
after the recovery delay — the server never silently drops a silo);
``on_revocation="exclude"`` instead drops the silo from the current
round only, for deadline-bound deployments.

The fold loop advances a virtual clock but charges each fold with the
*measured* wall-clock cost of the real ``StreamingAggregator.add``, so
reports mix simulated arrival latency with real aggregation compute.
Per-client fold completion times are threaded into
:class:`~repro.federated.server.RoundRecord` and (via
``CostModel.t_fold`` / ``async_round_time``) into the simulator's
round-time accounting.

Deadline-driven partial rounds (T_round folding)
------------------------------------------------
Barriering on the round *count* still holds the round hostage to one
heavy-tail straggler.  A :class:`RoundDeadline` policy closes the round
at ``T_round`` with whatever subset of ``c_msg_train`` messages arrived
by then — provided a configurable quorum (``min_clients`` fresh silos
and/or ``min_weight_frac`` of the round's deliverable example weight) is
met; the deadline silently *extends* to the earliest quorum-satisfying
arrival otherwise.  Three policies are provided: :class:`FixedDeadline`
(a constant T_round, the paper's per-round share of deadline ``T``),
:class:`QuantileDeadline` (a quantile of this round's arrival delays,
FedCostAware-style), and :class:`CostModelDeadline` (derived from
``CostModel.t_max()``, the worst-case round bound of Eq. 7).

A silo that misses the deadline is **never silently dropped**: its late
message is parked in the engine's
:class:`~repro.federated.agg_engine.CarryOverBuffer` and folded into the
*next* round's weighted average with a staleness discount
(``carry_discount ** rounds_late``), so every update eventually lands.
Repeated consecutive misses (``escalate_after``) mark the silo in
``FoldReport.escalations`` — a slow VM is treated like a soft fault per
§4.4, and callers (``AsyncFLServer.on_straggler``, the simulator's
``FaultToleranceModule.handle_straggler``) escalate it to
``DynamicScheduler.select_instance`` for a replacement instance.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import time
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from repro.core.control_plane import StragglerTracker
from repro.core.events import (
    DeadlineExpired,
    EventBus,
    RevocationOccurred,
    RoundClosed,
    StragglerEscalated,
    UpdateArrived,
    UpdateFolded,
)
from repro.core.revocation import RevocationModel, RevocationSampler
from .agg_engine import (
    AgeDiscount,
    AggregationEngine,
    CarryEntry,
    CarryOverBuffer,
    ResolvedSchema,
    StalenessPolicy,
    UpdateSchema,
    as_update_schema,
    plan_for,
)
from .client import ClientResult
from .compression import (
    CompressedUpdate,
    StructuredUpdate,
    materialize_structured,
    materialize_update,
)

__all__ = [
    "ArrivalSchedule",
    "AsyncFLServer",
    "AsyncRoundEngine",
    "CallableDeadline",
    "ClientArrival",
    "CostModelDeadline",
    "DeterministicSchedule",
    "FixedDeadline",
    "FoldEvent",
    "FoldReport",
    "HeavyTailSchedule",
    "InstantSchedule",
    "QuantileDeadline",
    "RevocationInjector",
    "RoundDeadline",
]


# ---------------------------------------------------------------------------
# Arrival model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ClientArrival:
    """One client's ``c_msg_train`` arrival event on the round's virtual clock.

    ``re_arrival_s`` is the *recorded* §4.3 re-request arrival: the live
    socket transport physically restarts a crashed worker and measures
    when its retrained update lands, so the engine replays that measured
    time instead of computing ``revoke_at + recovery_delay + delay``.
    ``math.inf`` means the re-request never landed inside the round's
    horizon — the silo is excluded; None keeps the virtual-clock model.
    """

    client_id: str
    delay_s: float                      # dispatch -> message-on-server
    revoke_at_s: Optional[float] = None  # spot VM revoked at this time (None = survives)
    re_arrival_s: Optional[float] = None  # measured re-request arrival (live transport)

    def delivered_before_revocation(self) -> bool:
        return self.revoke_at_s is None or self.revoke_at_s > self.delay_s

    def rerequest_arrival(self, recovery_delay_s: float) -> float:
        """When the re-requested update lands: the recorded time if the
        transport measured one, else the virtual-clock model."""
        if self.re_arrival_s is not None:
            return self.re_arrival_s
        assert self.revoke_at_s is not None
        return self.revoke_at_s + recovery_delay_s + self.delay_s


class ArrivalSchedule:
    """Maps a round to per-client arrival events (virtual seconds)."""

    def round_arrivals(
        self, round_idx: int, client_ids: Sequence[str]
    ) -> Dict[str, ClientArrival]:
        raise NotImplementedError


class InstantSchedule(ArrivalSchedule):
    """Every message is present at dispatch: the barrier server's timeline.

    With this schedule the async engine degenerates to one fused batch
    reduce (all inputs available at t=0), which is exactly the sync
    ``FLServer`` hot path."""

    def round_arrivals(
        self, round_idx: int, client_ids: Sequence[str]
    ) -> Dict[str, ClientArrival]:
        return {cid: ClientArrival(cid, 0.0) for cid in client_ids}


class DeterministicSchedule(ArrivalSchedule):
    """Fixed delays (scalar or per-client) and optional revocation times."""

    def __init__(
        self,
        delays: Union[float, Mapping[str, float]],
        revoke_at: Optional[Mapping[str, float]] = None,
    ) -> None:
        self.delays = delays
        self.revoke_at = dict(revoke_at or {})

    def round_arrivals(
        self, round_idx: int, client_ids: Sequence[str]
    ) -> Dict[str, ClientArrival]:
        out: Dict[str, ClientArrival] = {}
        for cid in client_ids:
            d = self.delays if isinstance(self.delays, (int, float)) else self.delays[cid]
            out[cid] = ClientArrival(cid, float(d), self.revoke_at.get(cid))
        return out


class HeavyTailSchedule(ArrivalSchedule):
    """Lognormal arrival delays with heavy-tail stragglers.

    Each client's delay is ``base_s * lognormal(0, sigma)``; clients in
    ``straggler_ids`` (or hit by ``straggler_prob`` each round) are
    multiplied by ``straggler_factor`` — the 1-slow-silo-in-8 shape the
    paper's multi-cloud traces show."""

    def __init__(
        self,
        base_s: float = 1.0,
        sigma: float = 0.25,
        straggler_ids: Sequence[str] = (),
        straggler_factor: float = 5.0,
        straggler_prob: float = 0.0,
        seed: int = 0,
    ) -> None:
        self.base_s = base_s
        self.sigma = sigma
        self.straggler_ids = frozenset(straggler_ids)
        self.straggler_factor = straggler_factor
        self.straggler_prob = straggler_prob
        self._rng = np.random.default_rng(seed)

    def round_arrivals(
        self, round_idx: int, client_ids: Sequence[str]
    ) -> Dict[str, ClientArrival]:
        out: Dict[str, ClientArrival] = {}
        for cid in client_ids:
            d = self.base_s * float(self._rng.lognormal(0.0, self.sigma))
            if cid in self.straggler_ids or (
                self.straggler_prob > 0.0
                and self._rng.uniform() < self.straggler_prob
            ):
                d *= self.straggler_factor
            out[cid] = ClientArrival(cid, d)
        return out


class RevocationInjector(ArrivalSchedule):
    """Decorate any schedule with Poisson spot revocations (§5.6 model).

    Events are drawn from the *global* Poisson process of
    :class:`repro.core.revocation.RevocationModel` against a running
    cross-round clock; each event landing inside a round's horizon
    revokes one uniformly-chosen still-running spot client (a client
    whose message has not yet arrived).  Events with no live spot
    victim are absorbed, matching the revocation module's semantics."""

    def __init__(
        self,
        inner: ArrivalSchedule,
        model: RevocationModel,
        spot_clients: Optional[Sequence[str]] = None,
        horizon_s: Optional[float] = None,
    ) -> None:
        self.inner = inner
        self.spot_clients = None if spot_clients is None else frozenset(spot_clients)
        self.horizon_s = horizon_s
        self._sampler: RevocationSampler = model.sampler()
        self._clock = 0.0
        self._next_event = self._sampler.next_event_after(0.0)

    def round_arrivals(
        self, round_idx: int, client_ids: Sequence[str]
    ) -> Dict[str, ClientArrival]:
        arrivals = dict(self.inner.round_arrivals(round_idx, client_ids))
        horizon = self.horizon_s
        if horizon is None:
            horizon = max((a.delay_s for a in arrivals.values()), default=0.0)
        round_end = self._clock + horizon

        while self._next_event <= round_end:
            t = self._next_event - self._clock  # round-local virtual time
            self._next_event = self._sampler.next_event_after(self._next_event)
            live_spot = sorted(
                cid
                for cid, a in arrivals.items()
                if a.delay_s > t
                and a.revoke_at_s is None
                and (self.spot_clients is None or cid in self.spot_clients)
            )
            victim = self._sampler.pick_victim(live_spot)
            if victim is None:
                continue
            a = arrivals[victim]
            arrivals[victim] = dataclasses.replace(a, revoke_at_s=t)
        self._clock = round_end
        return arrivals


# ---------------------------------------------------------------------------
# Deadline policies (T_round folding)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RoundDeadline:
    """Partial-round closure policy: when does the round stop waiting?

    ``deadline_s`` maps a round to its T_round on the round's virtual
    clock (seconds from ``s_msg_train`` dispatch).  The quorum fields
    guard against closing a round on too little evidence: the effective
    deadline extends to the earliest time at which at least
    ``min_clients`` fresh silos *and* ``min_weight_frac`` of the round's
    deliverable example weight have arrived.
    """

    min_clients: int = 1
    min_weight_frac: float = 0.0

    def __post_init__(self) -> None:
        # min_clients >= 1 guarantees every round has at least one fresh
        # fold (a zero-quorum deadline could park the whole cohort and
        # leave nothing to aggregate).
        if self.min_clients < 1:
            raise ValueError("min_clients must be >= 1")
        if not 0.0 <= self.min_weight_frac <= 1.0:
            raise ValueError("min_weight_frac must be in [0, 1]")

    def deadline_s(
        self, round_idx: int, arrivals: Mapping[str, ClientArrival]
    ) -> float:
        raise NotImplementedError

    def effective_deadline(
        self,
        round_idx: int,
        arrivals: Mapping[str, ClientArrival],
        deliveries: Mapping[str, float],
        weights: Mapping[str, float],
    ) -> float:
        """T_round extended (never shrunk) until the quorum is met.

        ``deliveries`` are final per-client delivery times *after* §4.3
        re-request resolution — a re-requested silo can still be the one
        that satisfies the quorum."""
        t = float(self.deadline_s(round_idx, arrivals))
        if not deliveries:
            return t
        order = sorted(deliveries.items(), key=lambda kv: (kv[1], kv[0]))
        need_n = min(int(self.min_clients), len(order))
        need_w = float(self.min_weight_frac) * sum(
            weights[cid] for cid, _ in order
        )
        got_n, got_w, t_quorum = 0, 0.0, -math.inf
        for cid, delivery in order:
            if got_n >= need_n and got_w + 1e-12 >= need_w:
                break
            got_n += 1
            got_w += weights[cid]
            t_quorum = delivery
        return max(t, t_quorum)


@dataclasses.dataclass(frozen=True)
class FixedDeadline(RoundDeadline):
    """Constant T_round: the per-round share of the application deadline T."""

    t_round_s: float = math.inf

    def deadline_s(
        self, round_idx: int, arrivals: Mapping[str, ClientArrival]
    ) -> float:
        return self.t_round_s


@dataclasses.dataclass(frozen=True)
class QuantileDeadline(RoundDeadline):
    """T_round = ``slack`` x the q-quantile of this round's arrival delays.

    Adapts to each round's arrival distribution (q=0.75, slack=1.0 closes
    on the fastest three quarters), which is the FedCostAware-style lever
    for cost control on spot capacity: the deadline tracks the cohort, not
    a wall-clock constant."""

    q: float = 0.75
    slack: float = 1.0

    def deadline_s(
        self, round_idx: int, arrivals: Mapping[str, ClientArrival]
    ) -> float:
        delays = [a.delay_s for a in arrivals.values()]
        if not delays:
            return 0.0
        return float(self.slack) * float(np.quantile(delays, self.q))


@dataclasses.dataclass(frozen=True)
class CallableDeadline(RoundDeadline):
    """Adapts a simulator-style ``(round_idx, {client: delay_s}) ->
    seconds`` callable to the live engine's :class:`RoundDeadline`
    surface — the ``Experiment`` builder uses this so one deadline spec
    drives both the virtual-clock and the live target."""

    fn: Any = None

    def deadline_s(
        self, round_idx: int, arrivals: Mapping[str, ClientArrival]
    ) -> float:
        if self.fn is None:
            raise ValueError("CallableDeadline needs a callable fn")
        offsets = {cid: a.delay_s for cid, a in arrivals.items()}
        return float(self.fn(round_idx, offsets))


@dataclasses.dataclass(frozen=True)
class CostModelDeadline(RoundDeadline):
    """T_round derived from the cost model's worst-case round bound.

    ``frac * CostModel.t_max()`` — t_max (Eq. 7's normalizer) is the
    worst round time over every client/VM/server-VM choice, so any silo
    slower than a ``frac`` share of it is pathological by the model's own
    accounting and belongs in the carry-over path."""

    cost_model: Any = None
    frac: float = 1.0

    def deadline_s(
        self, round_idx: int, arrivals: Mapping[str, ClientArrival]
    ) -> float:
        if self.cost_model is None:
            raise ValueError("CostModelDeadline needs a CostModel instance")
        return float(self.cost_model.deadline_from_t_max(self.frac))


# ---------------------------------------------------------------------------
# Fold engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FoldEvent:
    """One client fold on the round's virtual clock."""

    client_id: str
    arrival_s: float       # when its c_msg_train became foldable
    fold_start_s: float    # server picked it up (>= arrival; folds serialize)
    fold_end_s: float
    attempt: int = 1       # >1 after a revocation re-request
    revoked_at_s: Optional[float] = None
    weight: float = 0.0         # raw example weight folded (n_samples)
    folded_weight: float = 0.0  # after staleness discount (== weight when fresh)
    origin_round: Optional[int] = None  # set on carried-in (stale) folds only

    @property
    def is_stale(self) -> bool:
        return self.origin_round is not None


@dataclasses.dataclass
class FoldReport:
    """Result of one async round fold."""

    params: Any
    events: List[FoldEvent]
    excluded: List[str]           # silos dropped this round (exclude policy)
    rerequested: List[str]        # silos whose update was re-requested
    fold_times: Dict[str, float]  # client_id -> virtual fold-completion time
    round_span_s: float           # dispatch -> aggregated params ready
    busy_s: float                 # server time spent folding
    idle_s: float                 # round_span_s - busy_s (waiting on arrivals)
    # Counterfactual: wait for the last arrival, then do the SAME fold
    # work (last_arrival + busy_s).  With measured fold costs this is an
    # upper bound on the real sync FLServer's span — the barrier path
    # runs the fused batch reduce, which beats N incremental folds; see
    # benchmarks/async_round_bench.py for the measured-batch comparison.
    # Under a deadline the counterfactual is the PR-2 barrier-on-count
    # timeline: wait for every deliverable message (including the ones the
    # deadline deferred), then fold them all.
    barrier_span_s: float
    # Deadline accounting (None / empty when the round ran without one):
    deadline_s: Optional[float] = None        # effective close (quorum-extended)
    policy_deadline_s: Optional[float] = None  # raw T_round from the policy
    carried_over: List[str] = dataclasses.field(default_factory=list)
    carried_in: List[str] = dataclasses.field(default_factory=list)
    escalations: List[str] = dataclasses.field(default_factory=list)
    # Hierarchy: with ``fold_round(..., emit_partial=True)`` the round's
    # accumulator leaves as a PartialSum for a parent engine instead of
    # finalized params (params is None in that case).
    partial: Optional[Any] = None

    @property
    def span_saved_s(self) -> float:
        """Round time the streaming fold hides vs. barriering on the last
        arrival and then doing the same fold work (see barrier_span_s for
        why this bounds, rather than equals, the sync-server saving)."""
        return self.barrier_span_s - self.round_span_s


class AsyncRoundEngine:
    """Folds one round's client results in arrival order.

    Parameters
    ----------
    agg_engine : the fused :class:`AggregationEngine` (stats and the
        degenerate batch path route through it).
    on_revocation : §4.3 recovery rule for an update lost to revocation:
        ``"rerequest"`` (default — the replacement VM retrains, arriving
        ``recovery_delay_s + delay`` after the revocation, so the silo is
        still in the round's average) or ``"exclude"`` (drop the silo
        from this round only).
    recovery_delay_s : virtual VM replacement + restore time charged
        before a re-requested client restarts training.
    max_rerequests : re-request budget per client per round; a client
        revoked more than this many times is excluded.
    fold_cost_s : override the virtual cost of each fold (deterministic
        tests / simulators); None charges the measured wall-clock cost
        of the real ``StreamingAggregator.add``.
    deadline : default :class:`RoundDeadline` policy for every round
        (``fold_round`` can override per call).  None keeps the PR-2
        barrier-on-count behaviour: the round waits for every silo.
    carry_discount : staleness discount applied to a carried-over update's
        example weight per round of lateness (``weight * discount**age``).
    escalate_after : consecutive deadline misses by the same silo before
        it is reported in ``FoldReport.escalations`` and published as a
        :class:`~repro.core.events.StragglerEscalated` bus event (§4.4
        soft-fault escalation to the Dynamic Scheduler); the streak is
        tracked by the control plane's shared
        :class:`~repro.core.control_plane.StragglerTracker` and resets
        on an on-time delivery or an escalation.
    bus : control-plane :class:`~repro.core.events.EventBus` the engine
        publishes its typed fold trace on (UpdateArrived, UpdateFolded,
        RevocationOccurred, DeadlineExpired, StragglerEscalated,
        RoundClosed — all on the round's virtual clock).  None creates a
        private recording bus; pass ``repro.core.events.NULL_BUS`` to
        disable tracing entirely.
    """

    def __init__(
        self,
        agg_engine: Optional[AggregationEngine] = None,
        on_revocation: str = "rerequest",
        recovery_delay_s: float = 0.0,
        max_rerequests: int = 1,
        fold_cost_s: Optional[float] = None,
        deadline: Optional[RoundDeadline] = None,
        carry_discount: float = 0.5,
        escalate_after: int = 2,
        bus: Optional[EventBus] = None,
        schema: Union[None, UpdateSchema, Mapping[str, Any]] = None,
        staleness_policy: Optional[StalenessPolicy] = None,
    ) -> None:
        if on_revocation not in ("rerequest", "exclude"):
            raise ValueError("on_revocation must be 'rerequest' or 'exclude'")
        if not 0.0 <= carry_discount <= 1.0:
            raise ValueError("carry_discount must be in [0, 1]")
        self.agg_engine = agg_engine if agg_engine is not None else AggregationEngine()
        self.on_revocation = on_revocation
        self.recovery_delay_s = recovery_delay_s
        self.max_rerequests = max_rerequests
        self.fold_cost_s = fold_cost_s
        self.deadline = deadline
        self.carry_discount = carry_discount
        self.escalate_after = escalate_after
        self.bus = bus if bus is not None else EventBus()
        # Structured updates: rounds with a base fold through the
        # per-group StructuredStreamingAggregator under this schema.
        self.schema = as_update_schema(schema)
        self._resolved_schema: Optional[ResolvedSchema] = None
        # Carried-over weight rule; None keeps the PR-3 age discount
        # (AgeDiscount(carry_discount) — bit-identical arithmetic).
        self.staleness_policy = staleness_policy
        # Cross-round state: late updates awaiting their discounted fold,
        # and per-silo consecutive deadline-miss streaks (the same §4.4
        # policy object the simulator's control plane uses — validates
        # escalate_after >= 1).
        self.carry = CarryOverBuffer()
        self.stragglers = StragglerTracker(escalate_after)

    # ------------------------------------------------------------------
    def _resolve_schema(self, base_params: Any) -> Optional[ResolvedSchema]:
        if self.schema is None or base_params is None:
            return None
        plan = plan_for(base_params)
        if (self._resolved_schema is None
                or self._resolved_schema.plan.signature != plan.signature):
            self._resolved_schema = self.schema.resolve(base_params)
        return self._resolved_schema

    def _park_delta_norm(
        self, park_params: Any, base_params: Any
    ) -> Optional[float]:
        """||update - base||_2 at park time (drift-aware staleness input).

        Measured against whatever base the fold ran with; None when the
        round had no base (nothing to measure against) or the policy in
        use never reads drift."""
        policy = self.staleness_policy
        if base_params is None or policy is None or not policy.uses_drift:
            return None
        return float(self._distance_to_base(park_params, base_params))

    def _distance_to_base(self, params: Any, base_params: Any) -> float:
        """L2 distance between an update (full tree or per-group raw
        vectors) and the given global weights."""
        if isinstance(params, Mapping) and self.schema is not None:
            resolved = self._resolve_schema(base_params)
            if resolved is not None and all(
                k in dict(resolved.groups) for k in params
            ):
                total = 0.0
                for name, vec in params.items():
                    g = np.asarray(
                        resolved.group(name).flatten(base_params), np.float32
                    )
                    d = np.asarray(vec, np.float32) - g
                    total += float(np.dot(d, d))
                return math.sqrt(total)
        plan = plan_for(base_params)
        d_full = (np.asarray(plan.flatten(params), np.float32)
                  - np.asarray(plan.flatten(base_params), np.float32))
        return float(np.linalg.norm(d_full))

    def _carry_multiplier(
        self, entry: CarryEntry, round_idx: int, base_params: Any
    ) -> float:
        """The staleness multiplier for one parked entry.

        Default (no policy): the PR-3 age rule, same arithmetic as
        ``add_stale`` — ``discount ** age``.  A drift-aware policy also
        sees how far the CURRENT base sits from the parked update,
        relative to the update's own step size at park time."""
        policy: StalenessPolicy = (
            self.staleness_policy
            if self.staleness_policy is not None
            else AgeDiscount(self.carry_discount)
        )
        drift: Optional[float] = None
        if (policy.uses_drift and base_params is not None
                and entry.origin_delta_norm is not None):
            cur = self._distance_to_base(entry.params, base_params)
            drift = cur / max(float(entry.origin_delta_norm), 1e-12)
        return policy.effective_multiplier(entry, round_idx, drift=drift)

    # ------------------------------------------------------------------
    def fold_round(
        self,
        round_idx: int,
        results: Sequence[ClientResult],
        schedule: ArrivalSchedule,
        deadline: Optional[RoundDeadline] = None,
        base_params: Any = None,
        emit_partial: bool = False,
    ) -> FoldReport:
        """Fold one round's ``c_msg_train`` messages per the schedule.

        Without a deadline (engine default and ``deadline`` both None)
        the round barriers on the round count: every deliverable silo is
        in the average.  With one, the round closes at the effective
        (quorum-extended) T_round; messages arriving later are parked in
        the carry-over buffer and folded into the *next* round's average
        with a staleness discount.  Any previously parked updates are
        drained first — they are already sitting on the server.

        ``base_params`` (the round's global weights) switches the fold to
        the aggregator's flat/delta mode — required when results carry
        :class:`~repro.federated.compression.CompressedUpdate` payloads.
        A compressed update that misses the deadline is *materialized*
        (dequantized against this round's base) before it is parked: the
        delta is only meaningful against its origin round's base, which
        the next round no longer has, so the carry buffer always holds
        dense, base-independent parameters.

        ``emit_partial=True`` (hierarchy: this engine is a regional
        aggregator) finishes the round as a
        :class:`~repro.federated.agg_engine.PartialSum` on
        ``FoldReport.partial`` instead of finalized params
        (``FoldReport.params`` is None) — requires ``base_params``,
        since partial sums compose only against a shared base."""
        deadline = deadline if deadline is not None else self.deadline
        if not results:
            raise ValueError("fold_round needs at least one client result")
        if emit_partial and base_params is None:
            raise ValueError(
                "emit_partial requires base_params: partial sums compose "
                "only against a shared delta base"
            )
        by_id = {r.client_id: r for r in results}
        arrivals = schedule.round_arrivals(round_idx, list(by_id))

        if (
            deadline is None
            and not self.carry
            and base_params is None
            and all(
                a.delay_s == 0.0 and a.revoke_at_s is None
                for a in arrivals.values()
            )
        ):
            return self._fold_degenerate(round_idx, results)

        # Final delivery times after §4.3 re-request resolution, so the
        # deadline's quorum extension can see through a revocation: a
        # re-requested silo delivers at revoke + recovery + retrain.
        t_close: Optional[float] = None
        policy_t: Optional[float] = None
        if deadline is not None:
            deliveries: Dict[str, float] = {}
            for cid, a in arrivals.items():
                if a.delivered_before_revocation():
                    deliveries[cid] = a.delay_s
                elif self.on_revocation == "rerequest" and self.max_rerequests >= 1:
                    re_t = a.rerequest_arrival(self.recovery_delay_s)
                    if math.isfinite(re_t):
                        deliveries[cid] = re_t
            weights = {cid: float(by_id[cid].n_samples) for cid in deliveries}
            policy_t = float(deadline.deadline_s(round_idx, arrivals))
            t_close = deadline.effective_deadline(
                round_idx, arrivals, deliveries, weights
            )

        agg = self.agg_engine.streaming(
            base=base_params,
            base_round=round_idx if base_params is not None else None,
            schema=self.schema if base_params is not None else None,
        )
        events: List[FoldEvent] = []
        excluded: List[str] = []
        rerequested: List[str] = []
        carried_over: List[str] = []
        carried_in: List[str] = []
        escalations: List[str] = []
        server_free = 0.0
        busy = 0.0

        # Drain last round's stragglers first: their messages are already
        # on the server (arrival 0 on this round's clock), folded with the
        # staleness discount.
        for entry in self.carry.drain():
            t0 = time.monotonic()
            mult = self._carry_multiplier(entry, round_idx, base_params)
            w_eff = float(entry.weight) * mult
            agg.add(entry.params, w_eff, block=True, client_id=entry.client_id)
            measured = time.monotonic() - t0
            cost = self.fold_cost_s if self.fold_cost_s is not None else measured
            start = server_free
            server_free = start + cost
            busy += cost
            carried_in.append(entry.client_id)
            events.append(
                FoldEvent(entry.client_id, 0.0, start, server_free,
                          weight=entry.weight, folded_weight=w_eff,
                          origin_round=entry.origin_round)
            )
            self.bus.publish(
                UpdateFolded(server_free, round_idx, entry.client_id,
                             entry.weight, w_eff,
                             origin_round=entry.origin_round)
            )

        # Event heap: (effective arrival, seq, client_id, attempt, revoke_at).
        heap: List[Any] = []
        for seq, (cid, a) in enumerate(arrivals.items()):
            heapq.heappush(heap, (a.delay_s, seq, cid, 1, a.revoke_at_s))
        seq = len(heap)

        while heap:
            arrival, _, cid, attempt, revoke_at = heapq.heappop(heap)
            if revoke_at is not None and revoke_at <= arrival:
                # The silo died before its message landed: §4.3 recovery.
                self.bus.publish(
                    RevocationOccurred(revoke_at, cid, round_idx=round_idx)
                )
                if self.on_revocation == "rerequest" and attempt <= self.max_rerequests:
                    re_arrival = arrivals[cid].rerequest_arrival(
                        self.recovery_delay_s
                    )
                    if math.isinf(re_arrival):
                        # Recorded recovery (live transport): the
                        # re-request never landed inside the horizon.
                        excluded.append(cid)
                        continue
                    heapq.heappush(heap, (re_arrival, seq, cid, attempt + 1, None))
                    seq += 1
                    rerequested.append(cid)
                else:
                    excluded.append(cid)
                continue

            self.bus.publish(UpdateArrived(arrival, round_idx, cid, attempt))
            res = by_id[cid]
            if t_close is not None and arrival > t_close:
                # Missed the (quorum-extended) deadline: park the update
                # for the next round's discounted average and advance the
                # silo's miss streak toward §4.4 escalation.
                park_params = res.params
                if isinstance(park_params, CompressedUpdate):
                    # A compressed delta is pinned to THIS round's base;
                    # the next round's aggregator has a different one.
                    # Materialize now, while the origin base is on hand.
                    park_params = materialize_update(base_params, park_params)
                elif isinstance(park_params, StructuredUpdate):
                    # Same base-pinning applies per group: materialize to
                    # {group: raw fp32 values} before parking.
                    park_params = materialize_structured(
                        base_params, park_params,
                        self._resolve_schema(base_params),
                    )
                self.carry.defer(
                    CarryEntry(cid, park_params, float(res.n_samples),
                               origin_round=round_idx,
                               late_by_s=arrival - t_close,
                               origin_delta_norm=self._park_delta_norm(
                                   park_params, base_params))
                )
                carried_over.append(cid)
                streak = self.stragglers.record_miss(cid)
                if streak is not None:
                    escalations.append(cid)
                    self.bus.publish(
                        StragglerEscalated(arrival, cid, round_idx=round_idx,
                                           consecutive_misses=streak)
                    )
                continue

            t0 = time.monotonic()
            agg.add(res.params, res.n_samples, block=True, client_id=cid)
            measured = time.monotonic() - t0
            cost = self.fold_cost_s if self.fold_cost_s is not None else measured
            start = max(arrival, server_free)
            end = start + cost
            server_free = end
            busy += cost
            if t_close is not None:
                self.stragglers.clear(cid)
            events.append(
                FoldEvent(cid, arrival, start, end, attempt=attempt,
                          revoked_at_s=revoke_at,
                          weight=float(res.n_samples),
                          folded_weight=float(res.n_samples))
            )
            self.bus.publish(
                UpdateFolded(end, round_idx, cid,
                             float(res.n_samples), float(res.n_samples))
            )

        if not events:
            raise ValueError(
                "every silo's update was revoked and excluded; nothing to fold"
            )

        t0 = time.monotonic()
        partial = None
        if emit_partial:
            params = None
            partial = agg.export_partial()
            if hasattr(partial, "acc"):
                jax.block_until_ready(partial.acc)
            else:  # StructuredPartialSum: one accumulator per group
                for _, gpart in partial.groups:
                    jax.block_until_ready(gpart.acc)
        else:
            params = agg.result()
            jax.block_until_ready(params)
        finalize = (time.monotonic() - t0) if self.fold_cost_s is None else 0.0
        busy += finalize
        span = server_free + finalize
        if t_close is not None and carried_over:
            # The server cannot close a partial round before T_round — a
            # missing message could still land until then.
            span = max(server_free, t_close) + finalize
        last_arrival = max(e.arrival_s for e in events)
        if t_close is not None and carried_over:
            # Counterfactual barrier-on-count for THIS round's messages
            # only: wait for the last deliverable one (the deferred
            # stragglers included), then fold them all.  Carried-in folds
            # are excluded — the counterfactual barrier paid those in
            # their origin round — so each deferred fold is counted
            # exactly once across a run (here, at the mean measured fold
            # cost).
            fold_costs = [e.fold_end_s - e.fold_start_s for e in events]
            mean_cost = sum(fold_costs) / max(1, len(fold_costs))
            fresh_busy = finalize + sum(
                e.fold_end_s - e.fold_start_s for e in events if not e.is_stale
            )
            barrier_span = (
                max(deliveries.values())
                + fresh_busy + len(carried_over) * mean_cost
            )
        else:
            # A barrier server waits for the last arrival, then does the
            # same total aggregation work in one go.
            barrier_span = last_arrival + busy
        if t_close is not None:
            on_time = tuple(e.client_id for e in events if not e.is_stale)
            self.bus.publish(
                DeadlineExpired(t_close, round_idx, t_close,
                                policy_t if policy_t is not None else t_close,
                                on_time, tuple(carried_over))
            )
        self.bus.publish(
            RoundClosed(span, round_idx, span,
                        tuple(carried_over), tuple(carried_in))
        )
        return FoldReport(
            params=params,
            events=events,
            excluded=excluded,
            rerequested=rerequested,
            fold_times={e.client_id: e.fold_end_s for e in events},
            round_span_s=span,
            busy_s=busy,
            idle_s=max(0.0, span - busy),
            barrier_span_s=barrier_span,
            deadline_s=t_close,
            policy_deadline_s=policy_t,
            carried_over=carried_over,
            carried_in=carried_in,
            escalations=escalations,
            partial=partial,
        )

    # ------------------------------------------------------------------
    def _fold_degenerate(
        self, round_idx: int, results: Sequence[ClientResult]
    ) -> FoldReport:
        """All messages present at dispatch: one fused batch reduce.

        This is the sync ``FLServer`` path — the barrier protocol is the
        degenerate schedule of this engine, and it keeps the fused
        flatten-once/Pallas reduce (better than N streaming folds when
        every input is already in memory)."""
        t0 = time.monotonic()
        params = self.agg_engine.aggregate(
            [r.params for r in results], [r.n_samples for r in results]
        )
        jax.block_until_ready(params)
        agg_s = time.monotonic() - t0
        events = [
            FoldEvent(r.client_id, 0.0, 0.0, agg_s,
                      weight=float(r.n_samples),
                      folded_weight=float(r.n_samples))
            for r in results
        ]
        for r in results:
            self.bus.publish(UpdateArrived(0.0, round_idx, r.client_id))
            self.bus.publish(
                UpdateFolded(agg_s, round_idx, r.client_id,
                             float(r.n_samples), float(r.n_samples))
            )
        self.bus.publish(RoundClosed(agg_s, round_idx, agg_s))
        return FoldReport(
            params=params,
            events=events,
            excluded=[],
            rerequested=[],
            fold_times={r.client_id: agg_s for r in results},
            round_span_s=agg_s,
            busy_s=agg_s,
            idle_s=0.0,
            barrier_span_s=agg_s,
        )


# ---------------------------------------------------------------------------
# Async server
# ---------------------------------------------------------------------------

# Imported late: server.py's sync path lazily imports this module, so a
# top-level `from .server import FLServer` here completes the cycle only
# after server.py has fully loaded.
from .server import FLServer  # noqa: E402


class AsyncFLServer(FLServer):
    """FLServer whose rounds fold ``c_msg_train`` messages as they land.

    Identical protocol and results to :class:`FLServer` (same training,
    evaluation, checkpointing, and fault-hook semantics) but the
    aggregation phase runs through :class:`AsyncRoundEngine` with a
    pluggable :class:`ArrivalSchedule`, so round records carry per-client
    fold timestamps, the server's busy/idle split, and the counterfactual
    barrier span.

    ``round_deadline`` turns on deadline-driven partial rounds: rounds
    close at the policy's (quorum-extended) T_round, late silos carry
    into the next round's discounted average, and each §4.4 escalation
    (a silo with ``escalate_after`` consecutive misses) is published as
    a :class:`~repro.core.events.StragglerEscalated` event on the
    server's control-plane bus.  ``on_straggler(client_id, round_idx)``
    is a convenience hook invoked after each fold with *this server's*
    escalations — wire it to ``DynamicScheduler.select_instance`` to
    reassign the slow silo's VM; subscribe to the bus directly for the
    full typed trace (the same vocabulary the simulator emits).
    """

    def __init__(
        self,
        clients: Sequence[Any],
        initial_params: Any,
        schedule: Optional[ArrivalSchedule] = None,
        on_revocation: str = "rerequest",
        recovery_delay_s: float = 0.0,
        max_rerequests: int = 1,
        fold_cost_s: Optional[float] = None,
        round_deadline: Optional[RoundDeadline] = None,
        carry_discount: float = 0.5,
        escalate_after: int = 2,
        on_straggler: Optional[Any] = None,
        compression: Optional[Any] = None,
        schema: Union[None, UpdateSchema, Mapping[str, Any]] = None,
        staleness_policy: Optional[StalenessPolicy] = None,
        **kwargs: Any,
    ) -> None:
        from .compression import ClientCompressor, parse_compression

        super().__init__(clients, initial_params, **kwargs)
        self.schedule = schedule if schedule is not None else InstantSchedule()
        # `compression` turns on the compressed wire path: each client's
        # update is encoded as a quantized/sparsified delta against the
        # round's global weights (with per-client error feedback) and
        # folded via the aggregator's fused dequantize-and-fold path —
        # the virtual-clock twin of the live transport's worker-side
        # encoding, producing bit-identical updates for parity.
        self._compression = parse_compression(compression)
        self._compressors: Dict[str, ClientCompressor] = {}
        # `schema` turns on structured updates: each client's update is
        # re-encoded as a StructuredUpdate carrying only the schema's
        # named groups (per-group error feedback when compression is
        # also on), folded through the per-group masked aggregator.
        self._schema = as_update_schema(schema)
        self._staleness_policy = staleness_policy
        self._struct_encoders: Dict[str, Any] = {}
        self._round_engine = AsyncRoundEngine(
            self.agg_engine,
            on_revocation=on_revocation,
            recovery_delay_s=recovery_delay_s,
            max_rerequests=max_rerequests,
            fold_cost_s=fold_cost_s,
            deadline=round_deadline,
            carry_discount=carry_discount,
            escalate_after=escalate_after,
            bus=self.bus,
            schema=self._schema,
            staleness_policy=staleness_policy,
        )
        self.on_straggler = on_straggler
        self.fold_reports: List[FoldReport] = []

    @property
    def pending_carryover(self) -> CarryOverBuffer:
        """Late updates parked for the next round (empty without deadlines)."""
        return self._round_engine.carry

    def _compressor_for(self, client_id: str) -> Any:
        """The client's own compressor when it has one (client-owned
        error-feedback residual), else a server-held per-client one."""
        from .compression import ClientCompressor

        for c in self.clients:
            if str(c.client_id) == client_id:
                owned = getattr(c, "compressor", None)
                if owned is not None:
                    return owned
                break
        return self._compressors.setdefault(
            client_id, ClientCompressor(self._compression)
        )

    def _structured_encoder_for(self, client_id: str) -> Any:
        """Per-client structured encoder (holds per-group error feedback)."""
        from .compression import StructuredCompressor

        enc = self._struct_encoders.get(client_id)
        if enc is None:
            enc = StructuredCompressor(self._schema, self._compression)
            self._struct_encoders[client_id] = enc
        return enc

    def _fold_phase(self, round_idx: int, results: Sequence[ClientResult]) -> FoldReport:
        base = None
        if self._schema is not None:
            # Structured rounds: clients ship only the schema's named
            # groups.  self.params is still the dispatched global weights
            # (updated only after the fold), so it is both the encoding
            # base and the aggregation base.
            base = self.params
            results = [
                dataclasses.replace(
                    r,
                    params=self._structured_encoder_for(r.client_id).encode(
                        base, r.params, base_round=round_idx
                    ),
                )
                for r in results
            ]
        elif self._compression is not None:
            # self.params is still the round's dispatched global weights
            # here (updated only after the fold), so it is both the delta
            # base for encoding and the aggregation base for folding.
            base = self.params
            results = [
                dataclasses.replace(
                    r,
                    params=self._compressor_for(r.client_id).encode(
                        base, r.params, base_round=round_idx
                    ),
                )
                for r in results
            ]
        report = self._round_engine.fold_round(
            round_idx, results, self.schedule, base_params=base
        )
        self.fold_reports.append(report)
        # §4.4 escalation decisions are made by the control plane's
        # shared StragglerTracker and published as StragglerEscalated on
        # the bus (subscribe there for the typed trace).  The
        # on_straggler convenience hook is delivered from THIS server's
        # report — no bus subscription, so servers sharing a bus never
        # cross-dispatch each other's escalations, nothing pins the
        # server to a long-lived bus, a NULL_BUS (tracing off) still
        # recovers, and the hook fires after the round's FoldReport is
        # visible in fold_reports (the PR-3 contract).
        if self.on_straggler is not None:
            for cid in report.escalations:
                self.on_straggler(cid, round_idx)
        return report
