"""Two-level aggregation hierarchy: regional cohort folds that compose.

The paper's cross-silo deployment tops out at a handful of silos per
cloud, but the ROADMAP north star is serving millions of clients.  The
scaling shape comes from the aggregator-per-facility topology of
"Scalable Cross-Facility Federated Learning" (PAPERS.md): a tree of
streaming aggregators whose *weighted partial sums compose
associatively*.  Our flat-mode :class:`~repro.federated.agg_engine
.StreamingAggregator` already holds exactly that representation —
``acc = sum_i w_i * (update_i - base)`` plus the raw weight total — so a
hierarchy is an orchestration layer, not new math:

  clients ──► :class:`RegionalAggregator` (one per region; each runs the
  existing :class:`~repro.federated.async_server.AsyncRoundEngine` over
  its cohort with the full deadline / carry-over / §4.3 re-request
  machinery) ──► :class:`~repro.federated.agg_engine.PartialSum`
  (padded fp32 accumulator + weight total + client count + plan
  signature) ──► parent :class:`~repro.federated.agg_engine
  .StreamingAggregator.fold_partial` ──► finalized round params.

Because addition of the weighted deltas is what both levels compute,
the hierarchical result is *numerically identical* to the flat
single-engine fold over the same clients (property-tested in
``tests/test_hierarchy.py`` with exact-arithmetic inputs).

Three scale levers ride along:

- **Cohort sampling** (:class:`CohortSampler`): serve a 10k+ population
  by folding a seeded per-round cohort, cross-device-FL style.
- **Sharded parent folds** (:class:`ShardedPartialFolder`): the regional
  accumulators are stacked ``(R, L_pad)``, split across devices on a
  "pod" mesh axis, and reduced with a ``psum`` — the same mesh plumbing
  `pod_fedavg` uses (one device degenerates to a 1-shard mesh).
- **O(regions) parent work**: the parent folds R partials, not N
  clients, so the root's per-round cost is independent of cohort size.

The control-plane face is :class:`HierarchyCoordinator` (the concrete
``HierarchyAPI`` — see :mod:`repro.core.control_plane`), which publishes
typed :class:`~repro.core.events.RegionClosed` /
:class:`~repro.core.events.PartialFolded` events on the parent bus.
:class:`HierarchicalFLServer` drives real clients through it;
``Experiment.hierarchy(regions=..., cohort=...)`` is the builder knob.
"""
from __future__ import annotations

import dataclasses
import time
from typing import (
    Any,
    Callable,
    Dict,
    List,
    Mapping,
    Optional,
    Sequence,
    Tuple,
    Union,
)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.events import EventBus, NULL_BUS, PartialFolded, RegionClosed
from .agg_engine import (
    AggregationEngine,
    CarryEntry,
    PartialSum,
    StreamingAggregator,
    StructuredPartialSum,
    as_update_schema,
)
from .async_server import (
    ArrivalSchedule,
    AsyncFLServer,
    AsyncRoundEngine,
    FoldReport,
    InstantSchedule,
    RoundDeadline,
)
from .client import ClientResult

__all__ = [
    "CohortSampler",
    "HierarchicalFLServer",
    "HierarchyCoordinator",
    "HierarchyFoldReport",
    "RegionalAggregator",
    "ShardedPartialFolder",
    "as_cohort_sampler",
    "partition_regions",
]


# ---------------------------------------------------------------------------
# Cohort sampling
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CohortSampler:
    """Seeded per-round cohort selection over a client population.

    Exactly one of ``fraction`` (in ``(0, 1]``) or ``size`` (>= 1) picks
    the cohort; sampling is uniform without replacement, deterministic
    per ``(seed, round_idx)`` (the rng is re-derived every round, so
    replays and sim/live parity hold regardless of call order), and the
    returned cohort preserves the population's order."""

    fraction: Optional[float] = None
    size: Optional[int] = None
    seed: int = 0

    def __post_init__(self) -> None:
        if (self.fraction is None) == (self.size is None):
            raise ValueError(
                "specify exactly one of fraction= or size= for the cohort"
            )
        if self.fraction is not None and not (0.0 < self.fraction <= 1.0):
            raise ValueError(
                f"cohort fraction must be in (0, 1], got {self.fraction}"
            )
        if self.size is not None and self.size < 1:
            raise ValueError(f"cohort size must be >= 1, got {self.size}")

    def cohort_size(self, population: int) -> int:
        """Number of clients a round folds (at least 1, at most all)."""
        if population < 1:
            raise ValueError("cannot sample from an empty population")
        if self.size is not None:
            return min(self.size, population)
        assert self.fraction is not None
        return max(1, min(population, int(round(population * self.fraction))))

    def sample(self, round_idx: int, client_ids: Sequence[str]) -> List[str]:
        """The round's cohort, in stable population order."""
        ids = list(client_ids)
        k = self.cohort_size(len(ids))
        if k >= len(ids):
            return ids
        rng = np.random.default_rng((self.seed, round_idx))
        idx = np.sort(rng.choice(len(ids), size=k, replace=False))
        return [ids[int(i)] for i in idx]


def as_cohort_sampler(
    cohort: Union[None, float, int, CohortSampler], seed: int = 0
) -> Optional[CohortSampler]:
    """Coerce the user-facing cohort knob: None (fold everyone), a float
    fraction, an int fixed size, or a ready :class:`CohortSampler`."""
    if cohort is None or isinstance(cohort, CohortSampler):
        return cohort
    if isinstance(cohort, bool):
        raise ValueError("cohort must be a fraction, a size, or a CohortSampler")
    if isinstance(cohort, int):
        return CohortSampler(size=cohort, seed=seed)
    if isinstance(cohort, float):
        return CohortSampler(fraction=cohort, seed=seed)
    raise ValueError(
        f"cohort must be None, a float fraction, an int size, or a "
        f"CohortSampler; got {type(cohort).__name__}"
    )


# ---------------------------------------------------------------------------
# Region partitioning
# ---------------------------------------------------------------------------

def partition_regions(
    client_ids: Sequence[str],
    regions: Union[int, Mapping[str, Sequence[str]]],
) -> Dict[str, List[str]]:
    """Client -> region assignment, validated.

    ``regions`` is either an int (round-robin into ``region0..regionR-1``
    — a stand-in for real geography) or an explicit mapping of region id
    to client ids.  Every client must land in exactly one region and
    every region must be non-empty."""
    ids = [str(c) for c in client_ids]
    if isinstance(regions, int):
        if regions < 1:
            raise ValueError(f"need at least one region, got {regions}")
        if regions > len(ids):
            raise ValueError(
                f"{regions} regions for {len(ids)} clients: every region "
                "needs at least one client"
            )
        out: Dict[str, List[str]] = {f"region{i}": [] for i in range(regions)}
        for i, cid in enumerate(ids):
            out[f"region{i % regions}"].append(cid)
        return out
    seen: Dict[str, str] = {}
    mapped: Dict[str, List[str]] = {}
    for rid, cids in regions.items():
        rcids = [str(c) for c in cids]
        if not rcids:
            raise ValueError(f"region {rid!r} has no clients")
        for cid in rcids:
            if cid in seen:
                raise ValueError(
                    f"client {cid!r} appears in regions {seen[cid]!r} "
                    f"and {rid!r}"
                )
            seen[cid] = str(rid)
        mapped[str(rid)] = rcids
    if not mapped:
        raise ValueError("region mapping is empty")
    return mapped


# ---------------------------------------------------------------------------
# Regional aggregator
# ---------------------------------------------------------------------------

class RegionalAggregator:
    """One region's cohort folds, exported as composable partial sums.

    Wraps its own :class:`~repro.federated.async_server.AsyncRoundEngine`
    — the region keeps private per-region state (carry-over buffer,
    straggler streaks, re-request budget), so deadline-driven partial
    rounds and §4.3 revocation recovery behave exactly as they do on a
    flat server, just scoped to the region's clients.  The engine's own
    bus defaults to :data:`~repro.core.events.NULL_BUS` (a 16-region x
    10k-client round would otherwise record every per-fold event); the
    parent-level :class:`~repro.core.events.RegionClosed` /
    :class:`~repro.core.events.PartialFolded` summaries are published by
    the coordinator."""

    def __init__(
        self,
        region_id: str,
        client_ids: Sequence[str],
        engine: AsyncRoundEngine,
    ) -> None:
        self.region_id = str(region_id)
        self.client_ids = [str(c) for c in client_ids]
        self.engine = engine

    def fold_region(
        self,
        round_idx: int,
        results: Sequence[ClientResult],
        schedule: ArrivalSchedule,
        base_params: Any,
        deadline: Optional[RoundDeadline] = None,
    ) -> FoldReport:
        """Run the region's round; the report carries a
        :class:`~repro.federated.agg_engine.PartialSum` (tagged with this
        region's id) instead of finalized params."""
        report = self.engine.fold_round(
            round_idx, results, schedule, deadline=deadline,
            base_params=base_params, emit_partial=True,
        )
        assert report.partial is not None
        report.partial = dataclasses.replace(
            report.partial, region_id=self.region_id
        )
        return report


# ---------------------------------------------------------------------------
# Sharded parent folds (pod mesh + psum)
# ---------------------------------------------------------------------------

class ShardedPartialFolder:
    """Reduce regional accumulators across devices with a pod-axis psum.

    The R regional ``(L_pad,)`` fp32 accumulators are stacked into an
    ``(R, L_pad)`` buffer, split along the "pod" mesh axis (rows padded
    with zeros to a multiple of the pod size — zero rows are exact
    no-ops for a sum), each device sums its local rows, and a
    ``jax.lax.psum`` over the pod axis produces the replicated total.
    This is the same mesh plumbing `pod_fedavg` uses for replica stacks;
    on a single-device host the mesh degenerates to one shard and the
    math is unchanged."""

    def __init__(self, mesh: Optional[Any] = None) -> None:
        if mesh is None:
            mesh = jax.make_mesh((jax.device_count(),), ("pod",))
        self.mesh = mesh
        self.pod_size = int(mesh.shape["pod"])
        self._fn: Optional[Callable[..., Any]] = None

    def _reduce_fn(self) -> Callable[..., Any]:
        if self._fn is None:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P

            def local_sum(stack: Any) -> Any:
                return jax.lax.psum(jnp.sum(stack, axis=0), "pod")

            self._fn = jax.jit(
                shard_map(
                    local_sum, mesh=self.mesh,
                    in_specs=P("pod", None), out_specs=P(),
                )
            )
        return self._fn

    def reduce(self, accs: Sequence[Any]) -> Any:
        """Sum R accumulators into one ``(L_pad,)`` fp32 vector."""
        if not accs:
            raise ValueError("nothing to reduce")
        rows = jnp.stack([jnp.asarray(a, jnp.float32) for a in accs])
        pad = (-rows.shape[0]) % self.pod_size
        if pad:
            rows = jnp.concatenate(
                [rows, jnp.zeros((pad, rows.shape[1]), jnp.float32)]
            )
        return self._reduce_fn()(rows)


# ---------------------------------------------------------------------------
# Coordinator (the concrete HierarchyAPI)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class HierarchyFoldReport(FoldReport):
    """A :class:`~repro.federated.async_server.FoldReport` over the whole
    tree, plus per-region detail.

    The inherited fields summarize the round: ``params`` is the parent's
    finalized average, ``events``/``fold_times`` concatenate every
    region's folds, ``round_span_s`` is the slowest region's span (the
    regions run concurrently) plus the parent fold, and
    ``busy_s``/``carried_over``/``carried_in``/``escalations`` aggregate
    across regions."""

    region_reports: Dict[str, FoldReport] = dataclasses.field(
        default_factory=dict
    )
    partials: List[PartialSum] = dataclasses.field(default_factory=list)
    parent_fold_s: float = 0.0


class HierarchyCoordinator:
    """Orchestrates regional engines and the parent partial-sum fold.

    The concrete ``HierarchyAPI`` implementation
    (:mod:`repro.core.control_plane`): owns one
    :class:`RegionalAggregator` per region (each with a private
    :class:`~repro.federated.async_server.AsyncRoundEngine` sharing a
    single fused :class:`~repro.federated.agg_engine.AggregationEngine`),
    the optional :class:`CohortSampler`, and the parent fold —
    sequential :meth:`~repro.federated.agg_engine.StreamingAggregator
    .fold_partial` calls, or a :class:`ShardedPartialFolder` psum when
    ``sharded=True``.  Publishes :class:`~repro.core.events.RegionClosed`
    and :class:`~repro.core.events.PartialFolded` on ``bus``.
    """

    def __init__(
        self,
        region_map: Mapping[str, Sequence[str]],
        agg_engine: Optional[AggregationEngine] = None,
        sampler: Optional[CohortSampler] = None,
        deadline: Optional[RoundDeadline] = None,
        carry_discount: float = 0.5,
        escalate_after: int = 2,
        on_revocation: str = "rerequest",
        recovery_delay_s: float = 0.0,
        max_rerequests: int = 1,
        fold_cost_s: Optional[float] = None,
        sharded: bool = False,
        mesh: Optional[Any] = None,
        bus: Optional[EventBus] = None,
        schema: Optional[Any] = None,
        staleness_policy: Optional[Any] = None,
    ) -> None:
        if not region_map:
            raise ValueError("a hierarchy needs at least one region")
        self.agg_engine = agg_engine if agg_engine is not None else AggregationEngine()
        # Structured updates: every regional engine folds the schema's
        # named groups and exports a StructuredPartialSum; the parent
        # folds those per group under the same schema.
        self.schema = as_update_schema(schema)
        self.sampler = sampler
        self.bus = bus if bus is not None else EventBus()
        self.sharded = sharded
        self._folder = ShardedPartialFolder(mesh) if sharded else None
        self._regions: Dict[str, RegionalAggregator] = {}
        self._region_of: Dict[str, str] = {}
        for rid, cids in region_map.items():
            if not cids:
                raise ValueError(f"region {rid!r} has no clients")
            engine = AsyncRoundEngine(
                self.agg_engine,
                on_revocation=on_revocation,
                recovery_delay_s=recovery_delay_s,
                max_rerequests=max_rerequests,
                fold_cost_s=fold_cost_s,
                deadline=deadline,
                carry_discount=carry_discount,
                escalate_after=escalate_after,
                bus=NULL_BUS,
                schema=self.schema,
                staleness_policy=staleness_policy,
            )
            region = RegionalAggregator(str(rid), cids, engine)
            self._regions[region.region_id] = region
            for cid in region.client_ids:
                if cid in self._region_of:
                    raise ValueError(
                        f"client {cid!r} appears in regions "
                        f"{self._region_of[cid]!r} and {region.region_id!r}"
                    )
                self._region_of[cid] = region.region_id

    # -- HierarchyAPI ------------------------------------------------------
    @property
    def region_ids(self) -> List[str]:
        return list(self._regions)

    def region(self, region_id: str) -> RegionalAggregator:
        return self._regions[str(region_id)]

    def region_of(self, client_id: str) -> str:
        """The region a client folds through (KeyError if unmapped)."""
        return self._region_of[str(client_id)]

    def cohort_for(
        self, round_idx: int, client_ids: Sequence[str]
    ) -> List[str]:
        """The round's cohort (the whole population without a sampler)."""
        ids = [str(c) for c in client_ids]
        if self.sampler is None:
            return ids
        return self.sampler.sample(round_idx, ids)

    def pending_carryover(self) -> List[Tuple[str, CarryEntry]]:
        """Every region's parked late updates, as (region_id, entry)."""
        out: List[Tuple[str, CarryEntry]] = []
        for rid, region in self._regions.items():
            out.extend((rid, e) for e in region.engine.carry.snapshot())
        return out

    def fold_partials(
        self,
        round_idx: int,
        partials: Sequence[PartialSum],
        base_params: Any,
        now_s: float = 0.0,
    ) -> Any:
        """Fold regional partial sums into the round's finalized params.

        Sequential donated adds, or — ``sharded=True`` — one stacked
        psum over the pod mesh axis.  Either way the result is
        ``base + (sum_r acc_r) / (sum_r wsum_r)``: the flat fold's
        weighted average over every client in every partial."""
        ps = list(partials)
        if not ps:
            raise ValueError("no partial sums to fold")
        agg = self.agg_engine.streaming(
            base=base_params, base_round=round_idx, schema=self.schema
        )
        if self._folder is not None and len(ps) > 1:
            if self.schema is not None:
                combined = self._combine_structured_sharded(ps)
            else:
                head = ps[0]
                for p in ps[1:]:
                    if p.plan_signature != head.plan_signature:
                        raise ValueError(
                            f"partial sums disagree on the model structure: "
                            f"region {p.region_id!r} vs {head.region_id!r}"
                        )
                    if p.base_round != head.base_round:
                        raise ValueError(
                            f"partial sums disagree on the base round: region "
                            f"{p.region_id!r} has {p.base_round}, region "
                            f"{head.region_id!r} has {head.base_round}"
                        )
                combined = PartialSum(
                    acc=self._folder.reduce([p.acc for p in ps]),
                    wsum=sum(p.wsum for p in ps),
                    n_clients=sum(p.n_clients for p in ps),
                    plan_signature=head.plan_signature,
                    base_round=head.base_round,
                    region_id="<sharded>",
                )
            agg.fold_partial(combined, block=True)
        else:
            for p in ps:
                agg.fold_partial(p, block=True)
        for p in ps:
            self.bus.publish(
                PartialFolded(now_s, round_idx, p.region_id,
                              p.n_clients, p.wsum, base_round=p.base_round)
            )
        return agg.result()

    def _combine_structured_sharded(
        self, ps: Sequence[StructuredPartialSum]
    ) -> StructuredPartialSum:
        """Group-wise psum reduce of structured regional partials.

        Each group's accumulators are stacked and reduced over the pod
        axis independently (regions omitting a group contribute nothing
        to it); the combined partial carries the union of groups with
        per-group weight/count totals."""
        assert self._folder is not None
        head = ps[0]
        for p in ps[1:]:
            if p.schema_signature != head.schema_signature:
                raise ValueError(
                    f"structured partials disagree on the schema: region "
                    f"{p.region_id!r} vs {head.region_id!r}"
                )
            if p.base_round != head.base_round:
                raise ValueError(
                    f"structured partials disagree on the base round: "
                    f"region {p.region_id!r} has {p.base_round}, region "
                    f"{head.region_id!r} has {head.base_round}"
                )
        by_group: Dict[str, List[PartialSum]] = {}
        order: List[str] = []
        for p in ps:
            for name, gpart in p.groups:
                if name not in by_group:
                    by_group[name] = []
                    order.append(name)
                by_group[name].append(gpart)
        groups: List[Tuple[str, PartialSum]] = []
        for name in order:
            parts = by_group[name]
            sig = parts[0].plan_signature
            for gp in parts[1:]:
                if gp.plan_signature != sig:
                    raise ValueError(
                        f"group {name!r} partials disagree on the group "
                        f"plan signature"
                    )
            groups.append((name, PartialSum(
                acc=self._folder.reduce([gp.acc for gp in parts]),
                wsum=sum(gp.wsum for gp in parts),
                n_clients=sum(gp.n_clients for gp in parts),
                plan_signature=sig,
                base_round=head.base_round,
                region_id="<sharded>",
            )))
        return StructuredPartialSum(
            groups=tuple(groups),
            schema_signature=head.schema_signature,
            n_clients=sum(p.n_clients for p in ps),
            base_round=head.base_round,
            region_id="<sharded>",
        )

    def fold_round(
        self,
        round_idx: int,
        results: Sequence[ClientResult],
        schedule: Optional[ArrivalSchedule] = None,
        base_params: Any = None,
    ) -> HierarchyFoldReport:
        """One full hierarchical round: group by region, fold each
        region's cohort through its own engine, then fold the partial
        sums at the parent.  ``base_params`` (the round's global
        weights) is required — every level folds deltas against it."""
        if base_params is None:
            raise ValueError(
                "hierarchical folds need base_params: partial sums "
                "compose only against a shared delta base"
            )
        schedule = schedule if schedule is not None else InstantSchedule()
        grouped: Dict[str, List[ClientResult]] = {
            rid: [] for rid in self._regions
        }
        for res in results:
            cid = str(res.client_id)
            rid = self._region_of.get(cid)
            if rid is None:
                raise KeyError(f"client {cid!r} is not mapped to any region")
            grouped[rid].append(res)

        region_reports: Dict[str, FoldReport] = {}
        partials: List[PartialSum] = []
        span = 0.0
        for rid, region in self._regions.items():
            rres = grouped[rid]
            if not rres:
                # No cohort member this round; the region's carry (if
                # any) waits for its next populated round.
                continue
            rep = region.fold_region(round_idx, rres, schedule, base_params)
            region_reports[rid] = rep
            assert rep.partial is not None
            partials.append(rep.partial)
            span = max(span, rep.round_span_s)
            self.bus.publish(
                RegionClosed(rep.round_span_s, round_idx, rid,
                             rep.round_span_s, n_folded=len(rep.events),
                             carried_over=tuple(rep.carried_over))
            )
        if not partials:
            raise ValueError("no region folded any update this round")

        t0 = time.monotonic()
        params = self.fold_partials(round_idx, partials, base_params, now_s=span)
        jax.block_until_ready(params)
        parent_fold = time.monotonic() - t0

        deadlines = [
            r.deadline_s for r in region_reports.values()
            if r.deadline_s is not None
        ]
        events = [e for rep in region_reports.values() for e in rep.events]
        fold_times = {
            cid: t
            for rep in region_reports.values()
            for cid, t in rep.fold_times.items()
        }
        busy = sum(r.busy_s for r in region_reports.values()) + parent_fold
        total_span = span + parent_fold
        return HierarchyFoldReport(
            params=params,
            events=events,
            excluded=[c for r in region_reports.values() for c in r.excluded],
            rerequested=[
                c for r in region_reports.values() for c in r.rerequested
            ],
            fold_times=fold_times,
            round_span_s=total_span,
            busy_s=busy,
            idle_s=max(0.0, total_span - busy),
            barrier_span_s=max(
                (r.barrier_span_s for r in region_reports.values()),
                default=0.0,
            ) + parent_fold,
            deadline_s=max(deadlines) if deadlines else None,
            carried_over=[
                c for r in region_reports.values() for c in r.carried_over
            ],
            carried_in=[
                c for r in region_reports.values() for c in r.carried_in
            ],
            escalations=[
                c for r in region_reports.values() for c in r.escalations
            ],
            region_reports=region_reports,
            partials=partials,
            parent_fold_s=parent_fold,
        )


# ---------------------------------------------------------------------------
# Hierarchical FL server
# ---------------------------------------------------------------------------

class HierarchicalFLServer(AsyncFLServer):
    """An :class:`~repro.federated.async_server.AsyncFLServer` whose fold
    phase runs through a two-level :class:`HierarchyCoordinator`.

    Protocol per round: sample the cohort (when configured), train the
    cohort's clients, fold each region's updates through its own async
    engine, fold the regional partial sums at the parent, then evaluate
    the cohort on the new globals.  Compression (when configured)
    encodes each update as a tagged delta against the round's base,
    exactly as on the flat server.

    ``regions`` is an int (round-robin partition) or an explicit
    ``{region_id: [client_ids]}`` mapping; ``cohort`` a fraction, size,
    or :class:`CohortSampler`; ``sharded=True`` reduces the parent's
    partial stack with a pod-axis psum."""

    def __init__(
        self,
        clients: Sequence[Any],
        initial_params: Any,
        schedule: Optional[ArrivalSchedule] = None,
        regions: Union[int, Mapping[str, Sequence[str]]] = 4,
        cohort: Union[None, float, int, CohortSampler] = None,
        cohort_seed: int = 0,
        sharded: bool = False,
        mesh: Optional[Any] = None,
        on_revocation: str = "rerequest",
        recovery_delay_s: float = 0.0,
        max_rerequests: int = 1,
        fold_cost_s: Optional[float] = None,
        round_deadline: Optional[RoundDeadline] = None,
        carry_discount: float = 0.5,
        escalate_after: int = 2,
        on_straggler: Optional[Any] = None,
        compression: Optional[Any] = None,
        **kwargs: Any,
    ) -> None:
        super().__init__(
            clients, initial_params, schedule=schedule,
            on_revocation=on_revocation, recovery_delay_s=recovery_delay_s,
            max_rerequests=max_rerequests, fold_cost_s=fold_cost_s,
            round_deadline=round_deadline, carry_discount=carry_discount,
            escalate_after=escalate_after, on_straggler=on_straggler,
            compression=compression, **kwargs,
        )
        region_map = partition_regions(
            [str(c.client_id) for c in self.clients], regions
        )
        self.coordinator = HierarchyCoordinator(
            region_map,
            agg_engine=self.agg_engine,
            sampler=as_cohort_sampler(cohort, seed=cohort_seed),
            deadline=round_deadline,
            carry_discount=carry_discount,
            escalate_after=escalate_after,
            on_revocation=on_revocation,
            recovery_delay_s=recovery_delay_s,
            max_rerequests=max_rerequests,
            fold_cost_s=fold_cost_s,
            sharded=sharded,
            mesh=mesh,
            bus=self.bus,
            schema=self._schema,
            staleness_policy=self._staleness_policy,
        )

    @property
    def region_ids(self) -> List[str]:
        return self.coordinator.region_ids

    def _run_round(self, round_idx: int, restarted_from: Optional[str]) -> Any:
        # Narrow the round to its sampled cohort: training, folding,
        # evaluation, and client checkpointing all run over the cohort
        # (RoundDispatched, published before sampling, reports the full
        # population the round could have drawn from).
        population = self.clients
        cohort = set(
            self.coordinator.cohort_for(
                round_idx, [str(c.client_id) for c in population]
            )
        )
        self.clients = [c for c in population if str(c.client_id) in cohort]
        try:
            return super()._run_round(round_idx, restarted_from)
        finally:
            self.clients = population

    def _fold_phase(
        self, round_idx: int, results: Sequence[ClientResult]
    ) -> FoldReport:
        # The hierarchy always folds in flat/delta mode (partial sums
        # compose only against a shared base), so the round's dispatched
        # globals are the base whether or not the wire is compressed.
        base = self.params
        if self._schema is not None:
            results = [
                dataclasses.replace(
                    r,
                    params=self._structured_encoder_for(r.client_id).encode(
                        base, r.params, base_round=round_idx
                    ),
                )
                for r in results
            ]
        elif self._compression is not None:
            results = [
                dataclasses.replace(
                    r,
                    params=self._compressor_for(r.client_id).encode(
                        base, r.params, base_round=round_idx
                    ),
                )
                for r in results
            ]
        report = self.coordinator.fold_round(
            round_idx, results, self.schedule, base_params=base
        )
        self.fold_reports.append(report)
        if self.on_straggler is not None:
            for cid in report.escalations:
                self.on_straggler(cid, round_idx)
        return report
