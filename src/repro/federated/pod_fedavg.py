"""TPU-native cross-silo FedAvg: silos -> pods (DESIGN.md §3).

Each FL silo maps to one pod of the multi-pod mesh. Parameters and
optimizer state carry a leading `n_pods` axis sharded over the "pod" mesh
axis, so every pod holds an independent replica; local SGD steps are
`jax.vmap`ed over that axis (pure SPMD — XLA keeps all per-pod compute
pod-local). Once per round, FedAvg averages the replicas over the pod
axis — the ONLY cross-pod collective, an all-reduce of the parameter tree
over the slow DCN axis, amortized over `local_steps` ICI-local steps.
The reduce itself is `aggregation.fedavg_stacked`, which flattens the
whole replica stack into one (n_pods, L) buffer and lowers a single
fused contraction (Pallas `fedavg_reduce` on TPU) instead of a per-leaf
`tree.map`.
This is exactly the paper's communication pattern (rounds as
synchronization barriers) expressed in the TPU memory/collective
hierarchy.

The multi-pod dry-run lowers `fl_round_step` on the (pod, data, model)
mesh; single-pod shapes lower the plain `train_step`.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import ModelFamily
from .aggregation import fedavg_stacked


def make_train_step(model: ModelFamily, optimizer: Any):
    """Plain single-silo train step: (params, opt_state, batch) -> ..."""

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, loss

    return train_step


def init_pod_state(model: ModelFamily, optimizer: Any, rng: jax.Array, n_pods: int):
    """Per-pod replicated init: stack n_pods copies on a leading axis.

    All pods start from the same weights (the FL server broadcasts the
    initial model), so the stack is a broadcast of one init.
    """
    params = model.init(rng)
    opt_state = optimizer.init(params)
    stack = lambda t: jax.tree.map(lambda x: jnp.broadcast_to(x[None], (n_pods,) + x.shape), t)
    return stack(params), stack(opt_state)


def make_fl_round_step(
    model: ModelFamily,
    optimizer: Any,
    local_steps: int,
    pod_weights: Optional[jnp.ndarray] = None,
    unroll: bool = False,
):
    """Build the jittable FL round step.

    Args to the returned fn:
      stacked_params / stacked_opt : pytrees with leading n_pods axis
      batches : {name: (n_pods, local_steps, per_pod_batch, ...)}

    Returns (new_params, new_opt, mean_loss). After the round every pod
    holds the same aggregated weights (per-silo optimizer moments are kept
    silo-local, as in the paper — only weights flow through the server).
    """
    train_step = make_train_step(model, optimizer)

    def per_pod(params, opt_state, pod_batches):
        def body(carry, batch):
            p, o = carry
            p, o, loss = train_step(p, o, batch)
            return (p, o), loss

        (params, opt_state), losses = jax.lax.scan(
            body, (params, opt_state), pod_batches, unroll=unroll
        )
        return params, opt_state, jnp.mean(losses)

    def fl_round_step(stacked_params, stacked_opt, batches):
        params, opt_state, losses = jax.vmap(per_pod)(stacked_params, stacked_opt, batches)
        n_pods = losses.shape[0]
        w = pod_weights if pod_weights is not None else jnp.ones((n_pods,), jnp.float32)
        # FedAvg barrier: weighted mean over the pod axis, broadcast back.
        avg = fedavg_stacked(params, w)
        params = jax.tree.map(
            lambda a, p: jnp.broadcast_to(a[None], p.shape).astype(p.dtype), avg, params
        )
        return params, opt_state, jnp.mean(losses)

    return fl_round_step


def pod_batch_shape(
    cfg: ModelConfig, n_pods: int, local_steps: int, global_batch: int, seq_len: int
) -> Dict[str, Tuple[Tuple[int, ...], Any]]:
    """Shapes for the fl_round_step batch pytree (global batch split over
    pods)."""
    per_pod = global_batch // n_pods
    base = (n_pods, local_steps, per_pod)
    shapes: Dict[str, Tuple[Tuple[int, ...], Any]] = {
        "tokens": (base + (seq_len,), jnp.int32),
        "labels": (base + (seq_len,), jnp.int32),
    }
    if cfg.arch_type == "vlm":
        shapes["patch_embeds"] = (base + (cfg.n_image_tokens, cfg.d_model), cfg.activation_dtype)
    if cfg.arch_type == "encdec":
        shapes["frames"] = (base + (cfg.encoder_seq, cfg.d_model), cfg.activation_dtype)
    return shapes
