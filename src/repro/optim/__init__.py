from .optimizers import AdamW, AdamWState, SGDMomentum, SGDState, make_optimizer
from .schedules import constant, warmup_cosine

__all__ = [
    "AdamW",
    "AdamWState",
    "SGDMomentum",
    "SGDState",
    "constant",
    "make_optimizer",
    "warmup_cosine",
]
