from .optimizers import (
    AdamW,
    AdamWState,
    MaskedOptimizer,
    SGDMomentum,
    SGDState,
    make_optimizer,
    masked,
)
from .schedules import constant, warmup_cosine

__all__ = [
    "AdamW",
    "AdamWState",
    "MaskedOptimizer",
    "SGDMomentum",
    "SGDState",
    "constant",
    "make_optimizer",
    "masked",
    "warmup_cosine",
]
