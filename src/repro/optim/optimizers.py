"""Optimizers: AdamW and SGD-momentum with dtype-configurable state.

State dtype matters at jamba-1.5-large scale: fp32 Adam (m, v) for 398 B
params needs ~3.2 TB — over v5e-256's aggregate HBM once activations are
added. `state_dtype="bfloat16"` halves that (documented deviation in
DESIGN.md §3). The update math always runs in fp32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    learning_rate: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: str = "float32"
    schedule: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None

    def init(self, params: Any) -> AdamWState:
        dt = jnp.dtype(self.state_dtype)
        zeros = lambda p: jnp.zeros_like(p, dtype=dt)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
        )

    def update(self, grads: Any, state: AdamWState, params: Any) -> Tuple[Any, AdamWState]:
        step = state.step + 1
        lr = self.learning_rate if self.schedule is None else self.schedule(step)
        b1, b2 = self.b1, self.b2
        dt = jnp.dtype(self.state_dtype)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            mhat = mf / c1
            vhat = vf / c2
            delta = mhat / (jnp.sqrt(vhat) + self.eps) + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * delta
            return new_p.astype(p.dtype), mf.astype(dt), vf.astype(dt)

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamWState(step=step, m=new_m, v=new_v)


class SGDState(NamedTuple):
    step: jnp.ndarray
    momentum: Any


@dataclasses.dataclass(frozen=True)
class SGDMomentum:
    learning_rate: float = 0.01
    momentum: float = 0.9
    state_dtype: str = "float32"

    def init(self, params: Any) -> SGDState:
        dt = jnp.dtype(self.state_dtype)
        return SGDState(
            step=jnp.zeros((), jnp.int32),
            momentum=jax.tree.map(lambda p: jnp.zeros_like(p, dtype=dt), params),
        )

    def update(self, grads: Any, state: SGDState, params: Any) -> Tuple[Any, SGDState]:
        def upd(g, mbuf, p):
            mf = self.momentum * mbuf.astype(jnp.float32) + g.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - self.learning_rate * mf
            return new_p.astype(p.dtype), mf.astype(mbuf.dtype)

        out = jax.tree.map(upd, grads, state.momentum, params)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_params, SGDState(step=state.step + 1, momentum=new_m)


def make_optimizer(name: str, learning_rate: float, state_dtype: str = "float32", **kw):
    if name == "adamw":
        return AdamW(learning_rate=learning_rate, state_dtype=state_dtype, **kw)
    if name == "sgdm":
        return SGDMomentum(learning_rate=learning_rate, state_dtype=state_dtype, **kw)
    raise ValueError(f"unknown optimizer {name!r}")


@dataclasses.dataclass(frozen=True)
class MaskedOptimizer:
    """Freeze every leaf the selector does not match.

    Wraps any optimizer with the AdamW/SGDM ``init``/``update`` shape.
    Masked-out leaves get zero gradients AND are restored verbatim
    after the inner update — necessary because AdamW weight-decays
    every parameter it sees, which would silently train "frozen"
    leaves.  The adapter-FL use: ``masked(AdamW(...), ".lora_")``
    trains only injected LoRA factors.

    ``trainable`` is a substring matched against each leaf's path
    (``jax.tree_util.keystr`` form, e.g. ``"['fc0']['w.lora_a']"``) or
    a callable ``path_str -> bool``."""

    inner: Any
    trainable: Any

    def _mask(self, params: Any) -> Any:
        sel = self.trainable
        if callable(sel):
            match = sel
        else:
            needle = str(sel)
            match = lambda path: needle in path  # noqa: E731
        return jax.tree_util.tree_map_with_path(
            lambda path, _: bool(match(jax.tree_util.keystr(path))), params
        )

    def init(self, params: Any) -> Any:
        return self.inner.init(params)

    def update(self, grads: Any, state: Any, params: Any) -> Tuple[Any, Any]:
        mask = self._mask(params)
        masked_grads = jax.tree.map(
            lambda g, m: g if m else jnp.zeros_like(g), grads, mask
        )
        new_params, new_state = self.inner.update(masked_grads, state, params)
        new_params = jax.tree.map(
            lambda np_, p, m: np_ if m else p, new_params, params, mask
        )
        return new_params, new_state


def masked(inner: Any, trainable: Any) -> MaskedOptimizer:
    """``MaskedOptimizer`` shorthand: ``masked(AdamW(...), ".lora_")``."""
    return MaskedOptimizer(inner=inner, trainable=trainable)
