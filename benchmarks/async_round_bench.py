"""Async round engine benchmark: barrier vs streaming fold under stragglers.

Simulates the paper's worst multi-cloud case — 1 slow silo out of 8 — and
measures how much of the server's round time the async engine
(`repro.federated.async_server`) hides by folding each ``c_msg_train``
into the `StreamingAggregator` as it lands instead of barriering on the
straggler and then paying the full fused reduce.

Arrival delays run on the engine's virtual clock (7 silos at ``base``,
one at ``--straggler-factor * base``); every fold and the barrier's batch
reduce are *measured wall-clock* on real buffers, so the report mixes the
simulated cross-cloud latency with the true aggregation compute of this
backend.  Per shape it reports:

  barrier_round_s — straggler arrival + measured fused batch reduce
                    (the sync FLServer timeline);
  stream_round_s  — the async engine's round span (folds pipelined
                    behind arrivals, measured per-fold costs);
  idle_barrier_s / idle_stream_s — server idle time in each timeline;
  saved_frac      — (barrier - stream) / barrier round time.

Correctness is checked on every shape: streaming params must match the
batch reduce to max abs err <= 1e-5 (fp32).  Writes BENCH_async.json
(or --out) for PR-over-PR tracking, and prints ``name,us_per_call,
derived`` CSV rows on stdout like benchmarks/run.py.

Usage:
  PYTHONPATH=src python benchmarks/async_round_bench.py [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
import time
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.federated.agg_engine import AggregationEngine
from repro.federated.async_server import AsyncRoundEngine, DeterministicSchedule
from repro.federated.client import ClientResult

Row = Tuple[str, float, str]

N_LEAVES = 4      # split the flat param count over a few ragged leaves
N_CLIENTS = 8     # acceptance shape: 1 straggler in 8
# Compute-bound shapes only: below ~1M params the CPU reduce is
# dispatch-bound, N incremental folds cost more than one fused call, and
# the round-time delta sits inside timer noise — that regime is what the
# engine's degenerate batch path is for.  4M is the agg-bench acceptance
# shape.
FULL_PARAMS = [4_000_000, 16_000_000]
QUICK_PARAMS = [4_000_000]


def _make_results(n_clients: int, n_params: int, seed: int = 0) -> List[ClientResult]:
    rng = np.random.default_rng(seed)
    base = n_params // N_LEAVES
    sizes = [base] * (N_LEAVES - 1) + [n_params - base * (N_LEAVES - 1)]
    return [
        ClientResult(
            f"c{i}",
            {f"leaf{j}": jnp.asarray(rng.standard_normal(s).astype(np.float32))
             for j, s in enumerate(sizes)},
            n_samples=10 * (i + 1),
            train_time_s=0.0,
        )
        for i in range(n_clients)
    ]


def bench_shape(
    n_params: int,
    straggler_factor: float,
    base_delay_s: float,
    rounds: int = 5,
) -> Dict[str, Any]:
    results = _make_results(N_CLIENTS, n_params)
    weights = [r.n_samples for r in results]
    straggler = results[-1].client_id
    schedule = DeterministicSchedule(
        {r.client_id: base_delay_s * (straggler_factor if r.client_id == straggler else 1.0)
         for r in results}
    )
    straggler_arrival = base_delay_s * straggler_factor

    # Barrier timeline: fused batch reduce, measured (warm the jit first).
    batch_engine = AggregationEngine()
    batch_engine.aggregate([r.params for r in results], weights)
    batch_times, err = [], 0.0
    want = None
    for _ in range(rounds):
        t0 = time.monotonic()
        want = batch_engine.aggregate([r.params for r in results], weights)
        jax.block_until_ready(want)
        batch_times.append(time.monotonic() - t0)
    batch_s = statistics.median(batch_times)

    # Streaming timeline: real folds on the engine's virtual clock
    # (fold_cost_s=None charges measured wall-clock per fold). Warm once.
    stream_engine = AsyncRoundEngine(AggregationEngine())
    stream_engine.fold_round(0, results, schedule)
    reports = [stream_engine.fold_round(r + 1, results, schedule) for r in range(rounds)]
    # Per-metric medians, matching the barrier's median — taking the best
    # streaming round would bias the acceptance gate on noisy runners.
    stream_round_s = statistics.median(rep.round_span_s for rep in reports)
    stream_idle_s = statistics.median(rep.idle_s for rep in reports)
    stream_busy_s = statistics.median(rep.busy_s for rep in reports)
    err = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(reports[-1].params), jax.tree.leaves(want))
    )

    barrier_round_s = straggler_arrival + batch_s
    entry = {
        "n_clients": N_CLIENTS,
        "n_params": n_params,
        "base_delay_s": base_delay_s,
        "straggler_factor": straggler_factor,
        "batch_agg_s": round(batch_s, 6),
        "stream_busy_s": round(stream_busy_s, 6),
        "barrier_round_s": round(barrier_round_s, 6),
        "stream_round_s": round(stream_round_s, 6),
        "idle_barrier_s": round(straggler_arrival, 6),
        "idle_stream_s": round(stream_idle_s, 6),
        "saved_s": round(barrier_round_s - stream_round_s, 6),
        "saved_frac": round((barrier_round_s - stream_round_s) / barrier_round_s, 4),
        "max_abs_err": err,
    }
    print(
        f"[async] P={n_params//1000}k x{N_CLIENTS} (straggler {straggler_factor}x): "
        f"barrier={barrier_round_s*1e3:.1f}ms stream={stream_round_s*1e3:.1f}ms "
        f"(saved {entry['saved_frac']*100:.1f}%) idle {straggler_arrival*1e3:.1f}"
        f"->{stream_idle_s*1e3:.1f}ms err={err:.2e}",
        file=sys.stderr,
    )
    return entry


def run_grid(quick: bool = False, straggler_factor: float = 5.0,
             rounds: int = 5) -> Dict[str, Any]:
    params = QUICK_PARAMS if quick else FULL_PARAMS
    entries = []
    for p in params:
        # Tie the virtual cross-cloud delay to the real aggregation cost so
        # the saved time is visible at every shape: the straggler arrives
        # well after the fast silos, whose folds the engine hides.
        probe = _make_results(N_CLIENTS, p)
        eng = AggregationEngine()
        eng.aggregate([r.params for r in probe], [r.n_samples for r in probe])
        t0 = time.monotonic()
        jax.block_until_ready(
            eng.aggregate([r.params for r in probe], [r.n_samples for r in probe])
        )
        base_delay = max(5e-3, 0.5 * (time.monotonic() - t0))
        entries.append(bench_shape(p, straggler_factor, base_delay, rounds=rounds))

    ok = all(
        e["stream_round_s"] < e["barrier_round_s"]
        and e["idle_stream_s"] < e["idle_barrier_s"]
        and e["max_abs_err"] <= 1e-5
        for e in entries
    )
    report = {
        "backend": jax.default_backend(),
        "grid": "quick" if quick else "full",
        "n_clients": N_CLIENTS,
        "straggler_factor": straggler_factor,
        "entries": entries,
        "acceptance_ok": ok,
    }
    print(
        f"[async] acceptance (stream < barrier round+idle, err<=1e-5 on every "
        f"shape) -> {'OK' if ok else 'FAIL'}",
        file=sys.stderr,
    )
    return report


def bench_async_round() -> List[Row]:
    """run.py-compatible rows (quick grid)."""
    report = run_grid(quick=True, rounds=3)
    rows: List[Row] = []
    for e in report["entries"]:
        rows.append((
            f"async_round_{e['n_clients']}x{e['n_params']//1000}k",
            e["stream_round_s"] * 1e6,
            f"barrier_us={e['barrier_round_s']*1e6:.0f};saved_frac={e['saved_frac']}",
        ))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="small grid (CI smoke)")
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--straggler-factor", type=float, default=5.0)
    ap.add_argument("--out", default="BENCH_async.json")
    args = ap.parse_args()

    report = run_grid(quick=args.quick, straggler_factor=args.straggler_factor,
                      rounds=args.rounds)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"[async] wrote {args.out}", file=sys.stderr)

    print("name,us_per_call,derived")
    for e in report["entries"]:
        print(f"async_round_{e['n_clients']}x{e['n_params']},"
              f"{e['stream_round_s']*1e6:.1f},"
              f"barrier_us={e['barrier_round_s']*1e6:.1f};"
              f"saved_frac={e['saved_frac']}")
    if not report["acceptance_ok"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
