"""Cost-autopilot Pareto benchmark: autopilot vs the paper heuristic.

Sweeps a revocation-rate grid (mean seconds between spot revocations
k_r in {600, 1200, 2400} — calm rounds run ~160s, so these span
"storms most rounds" to "occasional faults", cf. the paper's §5.6
revocation study) on the virtual-clock simulator and compares two arms
under the SAME synthetic spot-price walk (identical billing, so the
delta is pure policy):

* **paper** — the static heuristic: Initial Mapping at on-demand
  prices, fixed T_round = deadline_s / n_rounds, fixed checkpoint
  cadence.  It still carries ``.autopilot(price_feed=...)`` so its VM
  ledger integrates the same moving quotes the autopilot pays.
* **autopilot** — the full loop (`repro.core.autopilot`): a $ budget at
  80% of the paper arm's median spend, budget-constrained markets and
  replacements, risk-aware checkpoint cadence, and the adaptive
  deadline controller retuning T_round from arrival quantiles.

Acceptance (ISSUE 9): the autopilot strictly dominates the paper
heuristic on cost at equal-or-better makespan in >= 2 of the 3
revocation settings, never losing on both axes at once, and the
controller's T_round trajectory is visible as ``DeadlineAdjusted``
events on BOTH drivers (each simulator arm, plus an in-process live
smoke).

Writes BENCH_cost.json (or --out) and prints ``name,us_per_call,
derived`` CSV rows like benchmarks/run.py.

Usage:
  PYTHONPATH=src python benchmarks/cost_bench.py [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import statistics
import sys
from typing import Any, Dict, List, Tuple

import jax
import numpy as np

from repro.core import (
    Experiment,
    SyntheticSpotFeed,
    cloudlab_environment,
    til_application,
)
from repro.core.events import BudgetExceeded, DeadlineAdjusted
from repro.federated.client import ClientResult, EvalResult

Row = Tuple[str, float, str]

K_R_GRID = (600.0, 1200.0, 2400.0)
SEEDS_FULL = (0, 1, 2, 3, 4)
SEEDS_QUICK = (1, 2, 3)
ROUNDS_FULL = 8
ROUNDS_QUICK = 6
FEED_SEED = 13
BUDGET_FRAC = 0.8    # autopilot budget = 80% of the paper arm's spend
TIME_TOL = 1.005     # "equal-or-better" makespan tolerance
STATIC_SLACK = 2.0   # paper arm: T_round = 2x the fault-free round time

# Autopilot knobs (see AutopilotSpec): close rounds at the 3-of-4
# arrival quantile instead of chasing a recovered straggler, flip a
# task's replacements to on-demand after its first spot revocation, and
# never stretch T_round past the paper's static allocation — the
# controller reclaims slack in calm rounds and cuts losses in stormy
# ones.
KNOBS: Dict[str, Any] = {
    "target_quantile": 0.75,
    "spot_fallback_after": 1,
}


def _chain(env: Any, app: Any, k_r: float, seed: int) -> Any:
    return (Experiment.on(env).app(app)
            .markets(clients="spot")
            .revocations(k_r=k_r, seed=seed)
            .checkpoints(every=10)
            .async_rounds(deadline=app.t_round))


def _median_arm(results: List[Any]) -> Dict[str, float]:
    return {
        "total_cost": statistics.median(r.total_cost for r in results),
        "total_time_s": statistics.median(r.total_time_s for r in results),
        "n_revocations": statistics.median(
            float(r.n_revocations) for r in results
        ),
        "n_deadline_misses": statistics.median(
            float(r.n_deadline_misses) for r in results
        ),
    }


def run_grid(quick: bool = False) -> Dict[str, Any]:
    env = cloudlab_environment()
    n_rounds = ROUNDS_QUICK if quick else ROUNDS_FULL
    seeds = SEEDS_QUICK if quick else SEEDS_FULL
    feed = SyntheticSpotFeed(seed=FEED_SEED)

    # The til app carries no training deadline, so calibrate the paper
    # arm's static T_round (Eq. 2) from one fault-free run: the round
    # time the Initial Mapping promises, times the usual 2x slack.
    app0 = til_application(n_rounds=n_rounds)
    calm = (Experiment.on(env).app(app0).markets(clients="spot")
            .checkpoints(every=10).async_rounds(deadline=None).simulate())
    nominal_round_s = calm.total_time_s / n_rounds
    app = dataclasses.replace(
        app0, deadline_s=STATIC_SLACK * nominal_round_s * n_rounds)
    print(
        f"[cost] calibrated nominal round {nominal_round_s:.1f}s, "
        f"static T_round {app.t_round:.1f}s",
        file=sys.stderr,
    )

    entries: List[Dict[str, Any]] = []
    trajectory: List[Dict[str, Any]] = []
    for k_r in K_R_GRID:
        paper = [
            _chain(env, app, k_r, s).autopilot(price_feed=feed).simulate()
            for s in seeds
        ]
        paper_m = _median_arm(paper)
        budget = BUDGET_FRAC * paper_m["total_cost"]
        auto = [
            _chain(env, app, k_r, s)
            .autopilot(budget=budget, price_feed=feed,
                       adaptive_deadline=True, risk_checkpointing=True,
                       max_t_round_s=app.t_round, **KNOBS)
            .simulate()
            for s in seeds
        ]
        auto_m = _median_arm(auto)
        adjusted = [e for e in auto[0].trace if isinstance(e, DeadlineAdjusted)]
        exceeded = sum(
            1 for r in auto
            if any(isinstance(e, BudgetExceeded) for e in r.trace)
        )
        if not trajectory and adjusted:
            trajectory = [
                {"round": e.round_idx, "old_s": e.old_t_round_s,
                 "new_s": e.new_t_round_s, "reason": e.reason}
                for e in adjusted
            ]
        cheaper = auto_m["total_cost"] < paper_m["total_cost"]
        not_slower = auto_m["total_time_s"] <= TIME_TOL * paper_m["total_time_s"]
        slower = auto_m["total_time_s"] > TIME_TOL * paper_m["total_time_s"]
        pricier = auto_m["total_cost"] > TIME_TOL * paper_m["total_cost"]
        entry = {
            "k_r": k_r,
            "budget_usd": budget,
            "paper": paper_m,
            "autopilot": auto_m,
            "deadline_adjustments": len(adjusted),
            "runs_over_budget": exceeded,
            "dominates": bool(cheaper and not_slower),
            "loses_both": bool(slower and pricier),
        }
        entries.append(entry)
        print(
            f"[cost] k_r={k_r:.0f}: paper ${paper_m['total_cost']:.3f}/"
            f"{paper_m['total_time_s']:.0f}s vs autopilot "
            f"${auto_m['total_cost']:.3f}/{auto_m['total_time_s']:.0f}s "
            f"(budget ${budget:.3f}, {len(adjusted)} DeadlineAdjusted) -> "
            f"{'DOMINATES' if entry['dominates'] else 'mixed'}",
            file=sys.stderr,
        )

    live = _live_smoke()
    n_dominating = sum(e["dominates"] for e in entries)
    acceptance_ok = (
        n_dominating >= 2
        and not any(e["loses_both"] for e in entries)
        and all(e["deadline_adjustments"] > 0 for e in entries)
        and live["deadline_adjustments"] > 0
    )
    return {
        "backend": jax.default_backend(),
        "grid": "quick" if quick else "full",
        "budget_frac": BUDGET_FRAC,
        "entries": entries,
        "deadline_trajectory": trajectory,
        "live": live,
        "n_dominating": n_dominating,
        "acceptance_ok": bool(acceptance_ok),
    }


class _Stub:
    """Duck-typed FLClient returning fixed params (no training)."""

    def __init__(self, client_id: str, params: Any, n: int) -> None:
        self.client_id = client_id
        self._params = params
        self._n = n

    def train(self, global_params: Any) -> ClientResult:
        return ClientResult(self.client_id, self._params, self._n, 0.0)

    def evaluate(self, aggregated_params: Any) -> EvalResult:
        return EvalResult(self.client_id, {"loss": 1.0}, self._n, 0.0)


def _live_smoke() -> Dict[str, Any]:
    """The same controller on the live driver: DeadlineAdjusted must be
    visible on the in-process engine's bus too (acceptance criterion)."""
    from repro.federated.async_server import DeterministicSchedule

    params = np.zeros(64, dtype=np.float32)
    clients = [_Stub(f"c{i}", params + i, 10) for i in range(4)]
    delays = {f"c{i}": 1.0 + 2.0 * i for i in range(4)}
    server = (Experiment()
              .async_rounds(deadline=5.0)
              .autopilot(adaptive_deadline=True)
              .serve(clients, params, schedule=DeterministicSchedule(delays)))
    server.run(6)
    adjusted = [e for e in server.bus.trace if isinstance(e, DeadlineAdjusted)]
    return {
        "deadline_adjustments": len(adjusted),
        "t_round_final_s": adjusted[-1].new_t_round_s if adjusted else 5.0,
    }


def bench_cost_autopilot() -> List[Row]:
    """run.py-compatible rows (quick grid)."""
    report = run_grid(quick=True)
    rows: List[Row] = []
    for e in report["entries"]:
        rows.append((
            f"cost_autopilot_kr{int(e['k_r'])}",
            e["autopilot"]["total_time_s"] * 1e6,
            f"cost_usd={e['autopilot']['total_cost']:.4f};"
            f"paper_cost_usd={e['paper']['total_cost']:.4f};"
            f"paper_time_s={e['paper']['total_time_s']:.0f};"
            f"adjusts={e['deadline_adjustments']};"
            f"dominates={int(e['dominates'])}",
        ))
    rows.append((
        "cost_autopilot_live_smoke",
        0.0,
        f"live_adjusts={report['live']['deadline_adjustments']};"
        f"acceptance_ok={int(report['acceptance_ok'])}",
    ))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="small grid (CI smoke)")
    ap.add_argument("--out", default="BENCH_cost.json")
    args = ap.parse_args()

    report = run_grid(quick=args.quick)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"[cost] wrote {args.out}", file=sys.stderr)

    print("name,us_per_call,derived")
    for e in report["entries"]:
        print(
            f"cost_autopilot_kr{int(e['k_r'])},"
            f"{e['autopilot']['total_time_s']*1e6:.1f},"
            f"cost_usd={e['autopilot']['total_cost']:.4f};"
            f"paper_cost_usd={e['paper']['total_cost']:.4f};"
            f"dominates={int(e['dominates'])}"
        )
    if not report["acceptance_ok"]:
        print(
            f"[cost] ACCEPTANCE FAILED: {report['n_dominating']}/3 settings "
            f"dominated, live_adjusts={report['live']['deadline_adjustments']}",
            file=sys.stderr,
        )
        sys.exit(1)
    print(
        f"[cost] acceptance ok: {report['n_dominating']}/3 settings "
        "dominated, trajectory visible on both drivers",
        file=sys.stderr,
    )


if __name__ == "__main__":
    main()
