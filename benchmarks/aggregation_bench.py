"""Aggregation-engine benchmark: seed per-leaf FedAvg vs the fused engine.

Sweeps a clients x param-count grid and, for each shape, times

  seed_us      — `aggregation.fedavg`, the per-leaf op-by-op oracle the
                 seed server used on every round;
  engine_us    — `AggregationEngine.aggregate`, the fused round path;
  flat_us      — `AggregationEngine.reduce_flat` on a pre-stacked (N, L)
                 buffer (the pod/replica-stack path), with achieved GB/s;
  stream_us    — `StreamingAggregator` folding clients one at a time.

Writes BENCH_agg.json next to the repo root (or --out) so the perf
trajectory is tracked PR-over-PR, and prints `name,us_per_call,derived`
CSV rows on stdout like benchmarks/run.py. The fused engine result is
checked against the oracle (max abs err <= 1e-5 in fp32) on every shape.

Usage:
  PYTHONPATH=src python benchmarks/aggregation_bench.py [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.federated.agg_engine import AggregationEngine
from repro.federated.aggregation import fedavg

try:  # same timing harness as the kernel benchmarks
    from .kernel_bench import _time_fn
except ImportError:  # standalone `python benchmarks/aggregation_bench.py`
    from kernel_bench import _time_fn

Row = Tuple[str, float, str]

# The acceptance shape (8 clients x 4M params) is in every grid.
FULL_GRID = [
    (4, 1_000_000), (8, 1_000_000), (16, 1_000_000),
    (4, 4_000_000), (8, 4_000_000), (16, 4_000_000),
    (8, 16_000_000),
]
QUICK_GRID = [(2, 65_536), (8, 4_000_000)]

N_LEAVES = 4  # mimic a real model: the flat param count split over leaves


def _make_trees(n_clients: int, n_params: int, seed: int = 0):
    """N structurally-identical pytrees, ragged leaves, ~n_params total."""
    rng = np.random.default_rng(seed)
    base = n_params // N_LEAVES
    sizes = [base] * (N_LEAVES - 1) + [n_params - base * (N_LEAVES - 1)]
    trees = [
        {f"leaf{i}": jnp.asarray(rng.standard_normal(s).astype(np.float32))
         for i, s in enumerate(sizes)}
        for _ in range(n_clients)
    ]
    weights = [float(i + 1) for i in range(n_clients)]
    return trees, weights


def bench_shape(n_clients: int, n_params: int, iters: int = 5) -> Dict[str, Any]:
    trees, weights = _make_trees(n_clients, n_params)
    engine = AggregationEngine()

    # correctness first: fused engine vs per-leaf oracle
    want = fedavg(trees, weights)
    got = engine.aggregate(trees, weights)
    err = max(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want))
    )

    seed_us = _time_fn(lambda: fedavg(trees, weights), iters=iters)
    engine_us = _time_fn(lambda: engine.aggregate(trees, weights), iters=iters)

    stacked = jnp.stack(
        [jnp.concatenate([jnp.ravel(l) for l in jax.tree.leaves(t)]) for t in trees]
    )
    w_arr = jnp.asarray(weights, jnp.float32)
    # donate=False: the same stacked buffer is reused across timing iters
    # (donation would consume it on the TPU path).
    flat_us = _time_fn(
        lambda: engine.reduce_flat(stacked, w_arr, donate=False), iters=iters
    )
    flat_bytes = stacked.nbytes + stacked.shape[1] * 4
    flat_gbs = flat_bytes / (flat_us * 1e-6) / 1e9

    def stream():
        agg = engine.streaming()
        for t, w in zip(trees, weights):
            agg.add(t, w)
        return agg.result()

    stream_us = _time_fn(stream, iters=iters)

    entry = {
        "n_clients": n_clients,
        "n_params": n_params,
        "seed_us": round(seed_us, 1),
        "engine_us": round(engine_us, 1),
        "flat_us": round(flat_us, 1),
        "stream_us": round(stream_us, 1),
        "speedup": round(seed_us / engine_us, 2),
        "flat_gbs": round(flat_gbs, 2),
        "max_abs_err": err,
    }
    print(
        f"[agg] N={n_clients} P={n_params//1000}k: seed={seed_us:.0f}us "
        f"engine={engine_us:.0f}us ({entry['speedup']}x) flat={flat_us:.0f}us "
        f"({flat_gbs:.1f} GB/s) stream={stream_us:.0f}us err={err:.2e}",
        file=sys.stderr,
    )
    return entry


def run_grid(quick: bool = False, iters: int = 5) -> Dict[str, Any]:
    grid = QUICK_GRID if quick else FULL_GRID
    entries = [bench_shape(n, p, iters=iters) for n, p in grid]
    acceptance = next(
        (e for e in entries if e["n_clients"] == 8 and e["n_params"] == 4_000_000),
        None,
    )
    report = {
        "backend": jax.default_backend(),
        "grid": "quick" if quick else "full",
        "iters": iters,
        "entries": entries,
        "acceptance_8x4M": acceptance,
    }
    if acceptance is not None:
        ok = acceptance["speedup"] >= 3.0 and acceptance["max_abs_err"] <= 1e-5
        report["acceptance_ok"] = ok
        print(
            f"[agg] acceptance 8x4M: {acceptance['speedup']}x "
            f"(target >=3x), err={acceptance['max_abs_err']:.2e} "
            f"(target <=1e-5) -> {'OK' if ok else 'FAIL'}",
            file=sys.stderr,
        )
    return report


def bench_aggregation() -> List[Row]:
    """run.py-compatible rows (quick grid, keeps the harness fast)."""
    report = run_grid(quick=True, iters=3)
    rows: List[Row] = []
    for e in report["entries"]:
        name = f"agg_engine_{e['n_clients']}x{e['n_params']//1_000_000}M" \
            if e["n_params"] >= 1_000_000 else \
            f"agg_engine_{e['n_clients']}x{e['n_params']//1000}k"
        rows.append((name, e["engine_us"],
                     f"speedup={e['speedup']}x;flat_gbs={e['flat_gbs']}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="small grid (CI smoke)")
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--out", default="BENCH_agg.json")
    args = ap.parse_args()

    report = run_grid(quick=args.quick, iters=args.iters)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"[agg] wrote {args.out}", file=sys.stderr)

    print("name,us_per_call,derived")
    for e in report["entries"]:
        print(f"agg_engine_{e['n_clients']}x{e['n_params']},{e['engine_us']},"
              f"speedup={e['speedup']}x")
    if report.get("acceptance_ok") is False:
        sys.exit(1)


if __name__ == "__main__":
    main()
