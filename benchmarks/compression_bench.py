"""Wire-compression benchmark: bytes/round + WAN round time + accuracy.

What does the compressed update path (``repro.federated.compression`` +
the fused dequantize-and-fold kernel) buy on a cross-silo WAN?  Per
codec (raw fp32 baseline, int8, fp16, top-k 10%):

* ``update_bytes_per_client`` — the serialized ``c_msg_train`` frame a
  silo actually puts on the inter-cloud link (compressed frames are
  fixed-width given the element count, so this is exact, not sampled);
* ``reduction_vs_fp32`` — dense fp32 bytes / wire bytes for that leg
  (the tentpole acceptance numbers: int8 >= 3x, topk(0.1) >= 5x);
* ``round_s_wan`` — simulated round time on a 100 Mbit/s WAN uplink:
  measured compute (client-side encode incl. error feedback + wire
  codec roundtrip + server-side fused fold) plus wire_bytes / link
  rate.  Silos upload in parallel, so the wire term is one client's
  frame, not the cohort sum.  Compression must be *strictly faster*
  here: the quantize/fold compute it adds is orders of magnitude
  cheaper than the WAN bytes it removes;
* ``final_loss`` / ``loss_delta_vs_raw`` — short convergence run (the
  linear toy cohort from the transport tests) through the real
  ``AsyncFLServer`` compressed path with error feedback: the accuracy
  price of quantization, which must stay within tolerance of raw.

Writes BENCH_compression.json (or --out) and prints
``name,us_per_call,derived`` CSV rows like benchmarks/run.py.

Usage:
  PYTHONPATH=src python benchmarks/compression_bench.py [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.federated.agg_engine import AggregationEngine, plan_for
from repro.federated.async_server import AsyncFLServer, DeterministicSchedule
from repro.federated.client import FLClient
from repro.federated.compression import (
    ClientCompressor,
    parse_compression,
    serialize_update,
    deserialize_update,
)
from repro.checkpoint.serializer import deserialize_pytree, serialize_pytree
from repro.optim import make_optimizer

Row = Tuple[str, float, str]

CODECS: List[Optional[str]] = [None, "int8", "fp16", "topk:0.1"]
N_CLIENTS = 4
ROUNDS = 6
WAN_BIT_S = 100e6  # simulated inter-cloud uplink, paper §5 scale
FULL_PARAMS = [250_000, 1_000_000]
QUICK_PARAMS = [250_000]
CONV_ROUNDS = 12


def _codec_name(codec: Optional[str]) -> str:
    return "fp32" if codec is None else codec.replace(":", "")


def bench_codec_shape(
    codec: Optional[str], n_params: int, rounds: int = ROUNDS
) -> Dict[str, Any]:
    """Measured encode+wire-roundtrip+fold compute for one codec, plus
    the exact wire size, on a (n_params,) model with N_CLIENTS silos."""
    spec = parse_compression(codec)
    rng = np.random.default_rng(0)
    base = {"w": jnp.zeros((n_params,), jnp.float32)}
    locals_ = [
        {"w": jnp.asarray(rng.standard_normal(n_params) * 0.1, jnp.float32)}
        for _ in range(N_CLIENTS)
    ]
    weights = [float(10 * (i + 1)) for i in range(N_CLIENTS)]
    engine = AggregationEngine()
    compressors = [ClientCompressor(spec) for _ in range(N_CLIENTS)] if spec else []

    def one_round() -> int:
        agg = engine.streaming(base=base if spec else None)
        frame_len = 0
        for i, (local, w) in enumerate(zip(locals_, weights)):
            if spec is None:
                frame = serialize_pytree(local)
                agg.add(deserialize_pytree(frame, base), w)
            else:
                update = compressors[i].encode(base, local)
                frame = serialize_update(update)
                agg.add(deserialize_update(frame), w)
            frame_len = len(frame)
        jax.block_until_ready(jax.tree.leaves(agg.result()))
        return frame_len

    wire_bytes = one_round()  # warm: jit traces, plan cache
    times = []
    for _ in range(rounds):
        t0 = time.perf_counter()
        one_round()
        times.append(time.perf_counter() - t0)
    compute_s = statistics.median(times)

    dense_bytes = plan_for(base).total_elems * 4
    wan_s = wire_bytes / (WAN_BIT_S / 8)  # parallel per-silo uplinks
    entry = {
        "codec": _codec_name(codec),
        "n_params": n_params,
        "n_clients": N_CLIENTS,
        "update_bytes_per_client": wire_bytes,
        "update_bytes_per_round": wire_bytes * N_CLIENTS,
        "dense_bytes_per_client": dense_bytes,
        "reduction_vs_fp32": round(dense_bytes / wire_bytes, 2),
        "compute_s": round(compute_s, 6),
        "wan_uplink_s": round(wan_s, 6),
        "round_s_wan": round(compute_s + wan_s, 6),
    }
    print(
        f"[compression] {_codec_name(codec)} P={n_params//1000}k: "
        f"{wire_bytes/1e3:.0f}kB/update ({entry['reduction_vs_fp32']}x), "
        f"compute={compute_s*1e3:.1f}ms wan={wan_s*1e3:.1f}ms "
        f"round={entry['round_s_wan']*1e3:.1f}ms",
        file=sys.stderr,
    )
    return entry


def _linear_cohort(seed: int = 7) -> List[FLClient]:
    class _Silo:
        def __init__(self, x: Any, y: Any) -> None:
            self.x, self.y = x, y

        def batches(self, batch_size: int, split: str = "train"):
            for i in range(0, len(self.x), batch_size):
                yield (self.x[i:i + batch_size], self.y[i:i + batch_size])

    def loss(params: Any, batch: Any) -> jnp.ndarray:
        x, y = batch
        return jnp.mean((x @ params["w"] - y) ** 2)

    rng = np.random.default_rng(seed)
    w_true = rng.standard_normal(3)
    clients = []
    for i in range(2):
        n = 24
        x = rng.standard_normal((n, 3))
        y = x @ w_true + 0.05 * rng.standard_normal(n)
        clients.append(
            FLClient(
                f"c{i}",
                _Silo(jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.float32)),
                loss,
                make_optimizer("sgdm", 1e-2),
                batch_size=8,
            )
        )
    return clients


def bench_convergence(rounds: int = CONV_ROUNDS) -> Dict[str, Any]:
    """Final loss per codec on the linear toy cohort (error feedback on)."""
    losses: Dict[str, float] = {}
    for codec in CODECS:
        server = AsyncFLServer(
            _linear_cohort(),
            {"w": jnp.zeros((3,), jnp.float32)},
            schedule=DeterministicSchedule(0.0),
            compression=codec,
        )
        result = server.run(rounds)
        losses[_codec_name(codec)] = float(result.rounds[-1].metrics["loss"])
    raw = losses["fp32"]
    report = {
        "rounds": rounds,
        "final_loss": {k: round(v, 6) for k, v in losses.items()},
        "loss_delta_vs_raw": {
            k: round(v - raw, 6) for k, v in losses.items() if k != "fp32"
        },
    }
    print(f"[compression] convergence: {report['final_loss']}", file=sys.stderr)
    return report


def run_grid(quick: bool = False, rounds: int = ROUNDS) -> Dict[str, Any]:
    params = QUICK_PARAMS if quick else FULL_PARAMS
    return {
        "backend": jax.default_backend(),
        "grid": "quick" if quick else "full",
        "wan_bit_s": WAN_BIT_S,
        "entries": [
            bench_codec_shape(c, p, rounds=rounds)
            for p in params
            for c in CODECS
        ],
        "convergence": bench_convergence(),
    }


def bench_compression() -> List[Row]:
    """run.py-compatible rows (quick grid)."""
    report = run_grid(quick=True, rounds=4)
    rows: List[Row] = []
    for e in report["entries"]:
        rows.append((
            f"compression_{e['codec']}_{e['n_params']//1000}k",
            e["round_s_wan"] * 1e6,
            f"wire_kb={e['update_bytes_per_client']/1e3:.0f};"
            f"reduction={e['reduction_vs_fp32']};"
            f"compute_us={e['compute_s']*1e6:.0f}",
        ))
    for k, d in report["convergence"]["loss_delta_vs_raw"].items():
        rows.append((f"compression_loss_delta_{k}", 0.0, f"delta={d}"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="small grid (CI smoke)")
    ap.add_argument("--rounds", type=int, default=ROUNDS)
    ap.add_argument("--out", default="BENCH_compression.json")
    args = ap.parse_args()

    report = run_grid(quick=args.quick, rounds=args.rounds)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"[compression] wrote {args.out}", file=sys.stderr)

    print("name,us_per_call,derived")
    for e in report["entries"]:
        print(
            f"compression_{e['codec']}_{e['n_params']},"
            f"{e['round_s_wan']*1e6:.1f},"
            f"wire_kb={e['update_bytes_per_client']/1e3:.0f};"
            f"reduction={e['reduction_vs_fp32']}"
        )


if __name__ == "__main__":
    main()
