"""Benchmark harness: one function per paper table/figure (+ kernels and
the roofline table). Prints ``name,us_per_call,derived`` CSV on stdout;
human-readable reports go to stderr.

``--check`` re-runs each grid-style benchmark on its quick grid and
compares the fresh report against the committed ``BENCH_*.json``
artifact at the repo root: boolean acceptance flags that were true when
committed must still be true, and shared numeric keys must stay within
a wide (5x) tolerance — quick grids are smaller than the committed full
grids, so this only catches gross regressions, not noise.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Callable, Dict, List

# Fresh numbers may drift from the committed artifact by machine and by
# quick-vs-full grid size; 5x flags order-of-magnitude breakage only.
CHECK_TOLERANCE = 5.0


def _check_registry() -> Dict[str, Callable[[], Dict[str, Any]]]:
    """Committed artifact -> fresh quick-grid report producer."""
    from .aggregation_bench import run_grid as agg
    from .async_round_bench import run_grid as async_round
    from .chaos_bench import run_grid as chaos
    from .compression_bench import run_grid as compression
    from .control_plane_bench import run_grid as control
    from .cost_bench import run_grid as cost
    from .deadline_bench import run_grid as deadline
    from .hierarchy_bench import run_grid as hierarchy
    from .structured_bench import run_grid as structured
    from .transport_bench import run_grid as transport

    return {
        "BENCH_agg.json": lambda: agg(quick=True),
        "BENCH_async.json": lambda: async_round(quick=True),
        "BENCH_chaos.json": lambda: chaos(quick=True),
        "BENCH_compression.json": lambda: compression(quick=True),
        "BENCH_control.json": lambda: control(quick=True),
        "BENCH_cost.json": lambda: cost(quick=True),
        "BENCH_deadline.json": lambda: deadline(quick=True),
        "BENCH_hierarchy.json": lambda: hierarchy(quick=True),
        "BENCH_structured.json": lambda: structured(quick=True),
        "BENCH_transport.json": lambda: transport(quick=True),
    }


def _compare(committed: Any, fresh: Any, path: str, problems: List[str]) -> None:
    """Walk shared keys; report acceptance-flag and order-of-magnitude
    regressions. Lists of dicts (per-shape entries) are skipped — quick
    and full grids sweep different shapes."""
    if isinstance(committed, bool):
        if committed and fresh is not True:
            problems.append(f"{path}: was true when committed, now {fresh!r}")
    elif isinstance(committed, (int, float)):
        if not isinstance(fresh, (int, float)) or isinstance(fresh, bool):
            problems.append(f"{path}: committed number, fresh {fresh!r}")
        elif committed > 1e-9:
            ratio = fresh / committed
            if not (1.0 / CHECK_TOLERANCE <= ratio <= CHECK_TOLERANCE):
                problems.append(
                    f"{path}: {fresh:.6g} vs committed {committed:.6g} "
                    f"(ratio {ratio:.2f} outside {CHECK_TOLERANCE}x)")
    elif isinstance(committed, dict) and isinstance(fresh, dict):
        for key in sorted(set(committed) & set(fresh)):
            _compare(committed[key], fresh[key], f"{path}.{key}", problems)
    elif isinstance(committed, list) and isinstance(fresh, list):
        if (len(committed) == len(fresh)
                and all(isinstance(v, (int, float)) for v in committed)):
            for i, (c, f) in enumerate(zip(committed, fresh)):
                _compare(c, f, f"{path}[{i}]", problems)


def check(root: str) -> int:
    registry = _check_registry()
    n_checked = 0
    failures: List[str] = []
    for fname, produce in sorted(registry.items()):
        artifact = os.path.join(root, fname)
        if not os.path.exists(artifact):
            print(f"[check] {fname}: no committed artifact, skipping",
                  file=sys.stderr)
            continue
        with open(artifact) as f:
            committed = json.load(f)
        print(f"[check] {fname}: re-running quick grid...", file=sys.stderr)
        try:
            fresh = produce()
        except Exception as e:  # noqa: BLE001 — report, keep checking
            failures.append(f"{fname}: fresh quick run failed: {e!r}")
            continue
        problems: List[str] = []
        _compare(committed, fresh, fname, problems)
        n_checked += 1
        if problems:
            failures.extend(problems)
            for p in problems:
                print(f"[check] FAIL {p}", file=sys.stderr)
        else:
            print(f"[check] {fname}: ok", file=sys.stderr)
    print(f"[check] {n_checked} artifacts checked, "
          f"{len(failures)} problems", file=sys.stderr)
    if failures:
        for p in failures:
            print(f"CHECK-FAIL,{p}")
        return 1
    print("CHECK-OK")
    return 0


def run_all() -> None:
    from .aggregation_bench import bench_aggregation
    from .async_round_bench import bench_async_round
    from .chaos_bench import bench_chaos
    from .compression_bench import bench_compression
    from .control_plane_bench import bench_control_plane
    from .cost_bench import bench_cost_autopilot
    from .deadline_bench import bench_deadline_round
    from .hierarchy_bench import bench_hierarchy
    from .kernel_bench import bench_kernels
    from .paper_tables import (
        bench_checkpoint_overhead,
        bench_failure_benchmarks,
        bench_failure_til,
        bench_initial_mapping,
        bench_poc_aws_gcp,
        bench_pre_scheduling,
    )
    from .roofline_bench import bench_roofline_table
    from .structured_bench import bench_structured
    from .transport_bench import bench_transport

    benches = [
        bench_pre_scheduling,       # Tables 3, 4
        bench_initial_mapping,      # §5.4
        bench_checkpoint_overhead,  # §5.5 / Fig. 2
        bench_failure_til,          # Tables 5, 6
        bench_failure_benchmarks,   # Tables 7, 8
        bench_poc_aws_gcp,          # §5.7
        bench_kernels,              # Pallas kernel hot spots
        bench_aggregation,          # fused FedAvg engine vs seed oracle
        bench_async_round,          # streaming fold vs barrier under stragglers
        bench_deadline_round,       # T_round partial rounds vs barrier-on-count
        bench_control_plane,        # event-bus overhead vs NULL_BUS (<5%)
        bench_transport,            # loopback socket rounds vs in-process
        bench_compression,          # compressed wire path: bytes + WAN round time
        bench_chaos,                # seeded fault soak: MTTR + rounds lost
        bench_hierarchy,            # regional partial-sum folds vs flat at 1k clients
        bench_structured,           # structured updates: LoRA wire win + sim/live parity
        bench_cost_autopilot,       # cost autopilot vs paper heuristic Pareto
        bench_roofline_table,       # §Roofline (from dry-run artifacts)
    ]
    print("name,us_per_call,derived")
    for bench in benches:
        try:
            for name, us, derived in bench():
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:  # noqa: BLE001 — keep the harness going
            print(f"{bench.__name__},0,ERROR:{e!r}")
            print(f"[ERROR] {bench.__name__}: {e!r}", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--check", action="store_true",
        help="compare fresh quick grids against committed BENCH_*.json")
    ap.add_argument(
        "--root", default=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        help="directory holding the committed BENCH_*.json artifacts")
    args = ap.parse_args()
    if args.check:
        sys.exit(check(args.root))
    run_all()


if __name__ == "__main__":
    main()
