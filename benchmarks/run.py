"""Benchmark harness: one function per paper table/figure (+ kernels and
the roofline table). Prints ``name,us_per_call,derived`` CSV on stdout;
human-readable reports go to stderr."""
from __future__ import annotations

import sys


def main() -> None:
    from .aggregation_bench import bench_aggregation
    from .async_round_bench import bench_async_round
    from .chaos_bench import bench_chaos
    from .compression_bench import bench_compression
    from .control_plane_bench import bench_control_plane
    from .deadline_bench import bench_deadline_round
    from .hierarchy_bench import bench_hierarchy
    from .kernel_bench import bench_kernels
    from .paper_tables import (
        bench_checkpoint_overhead,
        bench_failure_benchmarks,
        bench_failure_til,
        bench_initial_mapping,
        bench_poc_aws_gcp,
        bench_pre_scheduling,
    )
    from .roofline_bench import bench_roofline_table
    from .transport_bench import bench_transport

    benches = [
        bench_pre_scheduling,       # Tables 3, 4
        bench_initial_mapping,      # §5.4
        bench_checkpoint_overhead,  # §5.5 / Fig. 2
        bench_failure_til,          # Tables 5, 6
        bench_failure_benchmarks,   # Tables 7, 8
        bench_poc_aws_gcp,          # §5.7
        bench_kernels,              # Pallas kernel hot spots
        bench_aggregation,          # fused FedAvg engine vs seed oracle
        bench_async_round,          # streaming fold vs barrier under stragglers
        bench_deadline_round,       # T_round partial rounds vs barrier-on-count
        bench_control_plane,        # event-bus overhead vs NULL_BUS (<5%)
        bench_transport,            # loopback socket rounds vs in-process
        bench_compression,          # compressed wire path: bytes + WAN round time
        bench_chaos,                # seeded fault soak: MTTR + rounds lost
        bench_hierarchy,            # regional partial-sum folds vs flat at 1k clients
        bench_roofline_table,       # §Roofline (from dry-run artifacts)
    ]
    print("name,us_per_call,derived")
    for bench in benches:
        try:
            for name, us, derived in bench():
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:  # noqa: BLE001 — keep the harness going
            print(f"{bench.__name__},0,ERROR:{e!r}")
            print(f"[ERROR] {bench.__name__}: {e!r}", file=sys.stderr)


if __name__ == "__main__":
    main()
