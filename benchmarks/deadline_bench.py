"""Deadline-driven partial rounds benchmark: T_round folding vs the PR-2
barrier-on-count async engine under a heavy-tail straggler.

The acceptance shape is the paper's worst multi-cloud case — 8 silos,
one 5x slow.  The PR-2 engine (`AsyncRoundEngine` without a deadline)
folds messages as they land but still barriers the round on the *count*,
so every round pays the straggler's arrival.  Deadline mode
(`QuantileDeadline`) closes each round at a quantile of the arrivals:
the 7 fast silos' round closes immediately after their folds drain, and
the straggler's update is carried into the next round's average with a
staleness discount — never dropped.

Arrival delays run on the engine's virtual clock; every fold is
*measured wall-clock* on real buffers (`StreamingAggregator.add`), so
the report mixes simulated cross-cloud latency with the true aggregation
compute of this backend.  Per shape it reports:

  count_round_s    — barrier-on-count span (PR-2 timeline, median);
  deadline_round_s — partial-round span (median);
  idle_count_s / idle_deadline_s — server idle share of each timeline;
  saved_frac       — (count - deadline) / count round time;
  carried_per_round — stale folds drained per round (straggler lands);
  conservation_ok  — raw folded weight + still-parked weight over the
                     run == per-silo weight x rounds (the property the
                     test suite proves; re-checked here on real buffers).

Acceptance: deadline mode closes rounds strictly faster than
barrier-on-count on every shape AND conservation holds (the straggler's
update still lands, discounted, in a later round).

Writes BENCH_deadline.json (or --out) for PR-over-PR tracking and prints
``name,us_per_call,derived`` CSV rows on stdout like benchmarks/run.py.

Usage:
  PYTHONPATH=src python benchmarks/deadline_bench.py [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.federated.agg_engine import AggregationEngine
from repro.federated.async_server import (
    AsyncRoundEngine,
    DeterministicSchedule,
    QuantileDeadline,
)
from repro.federated.client import ClientResult

Row = Tuple[str, float, str]

N_LEAVES = 4      # split the flat param count over a few ragged leaves
N_CLIENTS = 8     # acceptance shape: 1 straggler in 8
STRAGGLER_FACTOR = 5.0
ROUNDS = 5
# Same compute-bound shapes as async_round_bench (see the note there on
# the dispatch-bound regime below ~1M params).
FULL_PARAMS = [4_000_000, 16_000_000]
QUICK_PARAMS = [4_000_000]


def _make_results(n_clients: int, n_params: int, seed: int = 0) -> List[ClientResult]:
    rng = np.random.default_rng(seed)
    base = n_params // N_LEAVES
    sizes = [base] * (N_LEAVES - 1) + [n_params - base * (N_LEAVES - 1)]
    return [
        ClientResult(
            f"c{i}",
            {f"leaf{j}": jnp.asarray(rng.standard_normal(s).astype(np.float32))
             for j, s in enumerate(sizes)},
            n_samples=10 * (i + 1),
            train_time_s=0.0,
        )
        for i in range(n_clients)
    ]


def bench_shape(n_params: int, base_delay_s: float, rounds: int = ROUNDS) -> Dict[str, Any]:
    results = _make_results(N_CLIENTS, n_params)
    straggler = results[-1].client_id
    schedule = DeterministicSchedule(
        {r.client_id: base_delay_s * (STRAGGLER_FACTOR if r.client_id == straggler else 1.0)
         for r in results}
    )
    total_weight = sum(r.n_samples for r in results)

    # PR-2 timeline: barrier on the round count (no deadline). Warm once.
    count_engine = AsyncRoundEngine(AggregationEngine())
    count_engine.fold_round(0, results, schedule)
    count_reports = [count_engine.fold_round(r + 1, results, schedule)
                     for r in range(rounds)]
    count_round_s = statistics.median(rep.round_span_s for rep in count_reports)
    count_idle_s = statistics.median(rep.idle_s for rep in count_reports)

    # Deadline timeline: close at the 7-of-8 quantile of arrivals — the
    # straggler misses, carries over, and lands discounted next round.
    deadline = QuantileDeadline(q=0.8, slack=1.2, min_clients=4)
    dl_engine = AsyncRoundEngine(AggregationEngine(), deadline=deadline,
                                 carry_discount=0.5, escalate_after=10**9)
    dl_reports = [dl_engine.fold_round(r + 1, results, schedule)
                  for r in range(1, rounds + 1)]
    dl_round_s = statistics.median(rep.round_span_s for rep in dl_reports)
    dl_idle_s = statistics.median(rep.idle_s for rep in dl_reports)
    carried = [len(rep.carried_in) for rep in dl_reports]

    # Weight conservation over the run: folded + still-parked == R x total.
    folded_raw = sum(e.weight for rep in dl_reports for e in rep.events)
    pending = dl_engine.carry.pending_weight()
    conservation_ok = abs(folded_raw + pending - rounds * total_weight) < 1e-6
    # The straggler's update must land (discounted) in rounds 2..R.
    straggler_landed = all(rep.carried_in == [straggler] for rep in dl_reports[1:])

    entry = {
        "n_clients": N_CLIENTS,
        "n_params": n_params,
        "base_delay_s": base_delay_s,
        "straggler_factor": STRAGGLER_FACTOR,
        "count_round_s": round(count_round_s, 6),
        "deadline_round_s": round(dl_round_s, 6),
        "idle_count_s": round(count_idle_s, 6),
        "idle_deadline_s": round(dl_idle_s, 6),
        "saved_s": round(count_round_s - dl_round_s, 6),
        "saved_frac": round((count_round_s - dl_round_s) / count_round_s, 4),
        "carried_per_round": carried,
        "conservation_ok": conservation_ok,
        "straggler_landed_discounted": straggler_landed,
    }
    print(
        f"[deadline] P={n_params//1000}k x{N_CLIENTS} (straggler "
        f"{STRAGGLER_FACTOR}x): count={count_round_s*1e3:.1f}ms "
        f"deadline={dl_round_s*1e3:.1f}ms (saved {entry['saved_frac']*100:.1f}%) "
        f"carried/round={carried} conserve={'OK' if conservation_ok else 'FAIL'}",
        file=sys.stderr,
    )
    return entry


def run_grid(quick: bool = False, rounds: int = ROUNDS) -> Dict[str, Any]:
    params = QUICK_PARAMS if quick else FULL_PARAMS
    entries = []
    for p in params:
        # Probe the real per-fold streaming cost on this shape (also warms
        # the jits) and make the virtual cross-cloud delay dominate it:
        # T_round folding pays off when arrival latency, not fold compute,
        # bounds the round — the cross-silo regime the paper targets.  A
        # delay tied to the (much cheaper) fused batch reduce would leave
        # the N-incremental-fold drain dominating both timelines and the
        # comparison inside timer noise.
        probe = _make_results(N_CLIENTS, p)
        probe_rep = AsyncRoundEngine(AggregationEngine()).fold_round(
            0, probe, DeterministicSchedule(1e-9)
        )
        fold_cost = probe_rep.busy_s / max(1, len(probe_rep.events))
        base_delay = max(5e-3, 5.0 * fold_cost)
        entries.append(bench_shape(p, base_delay, rounds=rounds))

    ok = all(
        e["deadline_round_s"] < e["count_round_s"]       # strictly faster
        and e["conservation_ok"]                         # nothing dropped
        and e["straggler_landed_discounted"]             # ... and it lands
        for e in entries
    )
    report = {
        "backend": jax.default_backend(),
        "grid": "quick" if quick else "full",
        "n_clients": N_CLIENTS,
        "straggler_factor": STRAGGLER_FACTOR,
        "entries": entries,
        "acceptance_ok": ok,
    }
    print(
        f"[deadline] acceptance (deadline < count round on every shape, "
        f"weight conserved, straggler lands discounted) -> "
        f"{'OK' if ok else 'FAIL'}",
        file=sys.stderr,
    )
    return report


def bench_deadline_round() -> List[Row]:
    """run.py-compatible rows (quick grid)."""
    report = run_grid(quick=True, rounds=3)
    rows: List[Row] = []
    for e in report["entries"]:
        rows.append((
            f"deadline_round_{e['n_clients']}x{e['n_params']//1000}k",
            e["deadline_round_s"] * 1e6,
            f"count_us={e['count_round_s']*1e6:.0f};saved_frac={e['saved_frac']}",
        ))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="small grid (CI smoke)")
    ap.add_argument("--rounds", type=int, default=ROUNDS)
    ap.add_argument("--out", default="BENCH_deadline.json")
    args = ap.parse_args()

    report = run_grid(quick=args.quick, rounds=args.rounds)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"[deadline] wrote {args.out}", file=sys.stderr)

    print("name,us_per_call,derived")
    for e in report["entries"]:
        print(f"deadline_round_{e['n_clients']}x{e['n_params']},"
              f"{e['deadline_round_s']*1e6:.1f},"
              f"count_us={e['count_round_s']*1e6:.1f};"
              f"saved_frac={e['saved_frac']}")
    if not report["acceptance_ok"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
