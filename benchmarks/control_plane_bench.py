"""Control-plane event-bus overhead benchmark.

Acceptance gate for the typed-event control plane: publishing the full
fold trace (UpdateArrived / UpdateFolded / DeadlineExpired /
RoundClosed / StragglerEscalated) on a recording ``EventBus`` must add
**<5%** to the PR-3 deadline-bench round time.

The scenario is exactly ``benchmarks/deadline_bench.py``'s acceptance
shape — 8 silos, one 5x straggler, ``QuantileDeadline`` partial rounds,
real ``StreamingAggregator`` folds on 4M/16M-param buffers — run twice
per round in interleaved A/B fashion: once on a recording ``EventBus``
(the default every ``AsyncRoundEngine`` now carries) and once on
``NULL_BUS`` (publish is a no-op).  Wall-clock medians per round give
``overhead_frac = (bus - null) / null``.

Writes BENCH_control.json (or --out) for PR-over-PR tracking, records
the matching BENCH_deadline.json round time when present, and prints
``name,us_per_call,derived`` CSV rows like benchmarks/run.py.

Usage:
  PYTHONPATH=src python benchmarks/control_plane_bench.py [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import sys
import time
from typing import Any, Dict, List, Tuple

import jax

from repro.core.events import NULL_BUS, EventBus
from repro.federated.agg_engine import AggregationEngine
from repro.federated.async_server import (
    AsyncRoundEngine,
    DeterministicSchedule,
    QuantileDeadline,
)

try:  # package context (benchmarks.run) vs standalone script
    from .deadline_bench import (
        N_CLIENTS,
        STRAGGLER_FACTOR,
        _make_results,
    )
except ImportError:  # pragma: no cover - standalone path
    from deadline_bench import N_CLIENTS, STRAGGLER_FACTOR, _make_results

Row = Tuple[str, float, str]

ROUNDS = 20  # min-of-N A/B: enough reps to sit on the noise floor
FULL_PARAMS = [4_000_000, 16_000_000]
QUICK_PARAMS = [4_000_000]
OVERHEAD_BUDGET = 0.05  # acceptance: bus adds <5% to the round time


def _deadline_engine(bus: EventBus) -> AsyncRoundEngine:
    return AsyncRoundEngine(
        AggregationEngine(),
        deadline=QuantileDeadline(q=0.8, slack=1.2, min_clients=4),
        carry_discount=0.5,
        escalate_after=10**9,
        bus=bus,
    )


def bench_shape(n_params: int, rounds: int = ROUNDS) -> Dict[str, Any]:
    results = _make_results(N_CLIENTS, n_params)
    straggler = results[-1].client_id
    delays = {
        r.client_id: 1.0 * (STRAGGLER_FACTOR if r.client_id == straggler else 1.0)
        for r in results
    }
    schedule = DeterministicSchedule(delays)

    engines = {
        "bus": _deadline_engine(EventBus()),
        "null": _deadline_engine(NULL_BUS),
    }
    for engine in engines.values():  # warm the jits / first-fold traces
        engine.fold_round(0, results, schedule)

    times: Dict[str, List[float]] = {"bus": [], "null": []}
    for r in range(1, rounds + 1):
        # Interleaved A/B, alternating order so allocator/GC drift hits
        # both arms symmetrically; the min is the noise-floor estimate.
        order = ("bus", "null") if r % 2 else ("null", "bus")
        for name in order:
            t0 = time.perf_counter()
            engines[name].fold_round(r, results, schedule)
            times[name].append(time.perf_counter() - t0)

    bus_s = min(times["bus"])
    null_s = min(times["null"])
    median_bus_s = statistics.median(times["bus"])
    median_null_s = statistics.median(times["null"])
    n_events = len(engines["bus"].bus.trace)
    overhead = (bus_s - null_s) / null_s
    entry = {
        "n_clients": N_CLIENTS,
        "n_params": n_params,
        "rounds": rounds,
        "bus_round_s": round(bus_s, 6),
        "null_round_s": round(null_s, 6),
        "bus_round_median_s": round(median_bus_s, 6),
        "null_round_median_s": round(median_null_s, 6),
        "events_recorded": n_events,
        "overhead_frac": round(overhead, 4),
        "overhead_ok": overhead < OVERHEAD_BUDGET,
    }
    print(
        f"[control] P={n_params//1000}k x{N_CLIENTS}: "
        f"null={null_s*1e3:.2f}ms bus={bus_s*1e3:.2f}ms "
        f"({n_events} events) overhead={overhead*100:+.2f}% "
        f"-> {'OK' if entry['overhead_ok'] else 'FAIL'}",
        file=sys.stderr,
    )
    return entry


def run_grid(quick: bool = False, rounds: int = ROUNDS) -> Dict[str, Any]:
    params = QUICK_PARAMS if quick else FULL_PARAMS
    entries = [bench_shape(p, rounds=rounds) for p in params]
    ok = all(e["overhead_ok"] for e in entries)

    # Cross-reference the PR-3 deadline benchmark when its report exists.
    deadline_ref = None
    ref_path = os.path.join(os.path.dirname(__file__), "..", "BENCH_deadline.json")
    if os.path.exists(ref_path):
        try:
            with open(ref_path) as f:
                report = json.load(f)
            deadline_ref = {
                e["n_params"]: e["deadline_round_s"] for e in report["entries"]
            }
        except (KeyError, json.JSONDecodeError):  # stale/foreign file
            deadline_ref = None

    result = {
        "backend": jax.default_backend(),
        "grid": "quick" if quick else "full",
        "overhead_budget_frac": OVERHEAD_BUDGET,
        "entries": entries,
        "deadline_bench_round_s": deadline_ref,
        "acceptance_ok": ok,
    }
    print(
        f"[control] acceptance (event bus adds <{OVERHEAD_BUDGET*100:.0f}% to "
        f"the deadline-bench round on every shape) -> {'OK' if ok else 'FAIL'}",
        file=sys.stderr,
    )
    return result


def bench_control_plane() -> List[Row]:
    """run.py-compatible rows (quick grid)."""
    report = run_grid(quick=True, rounds=10)
    rows: List[Row] = []
    for e in report["entries"]:
        rows.append((
            f"control_bus_{e['n_clients']}x{e['n_params']//1000}k",
            e["bus_round_s"] * 1e6,
            f"null_us={e['null_round_s']*1e6:.0f};"
            f"overhead_frac={e['overhead_frac']};"
            f"events={e['events_recorded']}",
        ))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="small grid (CI smoke)")
    ap.add_argument("--rounds", type=int, default=ROUNDS)
    ap.add_argument("--out", default="BENCH_control.json")
    args = ap.parse_args()

    report = run_grid(quick=args.quick, rounds=args.rounds)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"[control] wrote {args.out}", file=sys.stderr)

    print("name,us_per_call,derived")
    for e in report["entries"]:
        print(f"control_bus_{e['n_clients']}x{e['n_params']},"
              f"{e['bus_round_s']*1e6:.1f},"
              f"null_us={e['null_round_s']*1e6:.1f};"
              f"overhead_frac={e['overhead_frac']}")
    if not report["acceptance_ok"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
