"""Chaos recovery-cost benchmark (MTTR + rounds lost).

What does a seeded fault plan *cost*?  Runs the same paced stub cohort
through the wall-clock ``LiveRoundDriver`` twice:

* **fault-free** — no chaos, the baseline round cadence;
* **chaos** — a 6-fault seeded :class:`FaultPlan` (crash, slow,
  corrupt_frame, hang, a §4.4 cross-host revocation, and §4.3
  checkpoint sabotage) with heartbeats, reconnect/backoff, a
  ``DynamicScheduler`` for replacement VMs, and verified checkpoint
  managers — i.e. every hardening layer is live and paying its way.

Measures:

* ``fault_free_round_s`` / ``chaos_round_s`` — median round wall time;
* ``recovery_overhead_s`` — total extra wall paid for the whole plan;
* ``mttr_s`` — recovery overhead / faults injected (mean time to
  repair, §5.6's "time to recover" in miniature);
* ``rounds_lost`` — rounds whose fold lost cohort weight despite the
  recovery machinery (the framework's target is 0: every fault is
  re-requested, restarted, or restored within its round).

Writes BENCH_chaos.json (or --out), optionally the full chaos event
trace (--trace-out), and prints ``name,us_per_call,derived`` CSV rows
like benchmarks/run.py.

Usage:
  PYTHONPATH=src python benchmarks/chaos_bench.py [--quick] [--out PATH]
      [--trace-out PATH]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import statistics
import sys
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    Assignment,
    ClientSpec,
    CloudEnvironment,
    CostModel,
    DynamicScheduler,
    Experiment,
    FLApplication,
    MessageSizes,
    Provider,
    Region,
    VMType,
)
from repro.core.events import UpdateFolded, VMReplaced
from repro.checkpoint import ClientCheckpointManager, ServerCheckpointManager
from repro.federated.chaos import FaultPlan, FaultSpec, verify_fault_pairing
from repro.federated.client import ClientResult, EvalResult

Row = Tuple[str, float, str]

ROUNDS_FULL = 8
ROUNDS_QUICK = 5
N_PARAMS = 50_000
DELAYS = {"c0": 0.0, "c1": 0.02, "c2": 0.04}
N_EXAMPLES = {"c0": 12, "c1": 20, "c2": 16}


class PacedStub:
    """Duck-typed FLClient: fixed params, a deterministic per-round pace
    — isolates the *recovery* cost from any learning cost."""

    def __init__(self, client_id: str, params: Any, delay_s: float, n: int) -> None:
        self.client_id = client_id
        self._params = params
        self._delay_s = delay_s
        self._n = n

    def train(self, global_params: Any) -> ClientResult:
        time.sleep(self._delay_s)
        return ClientResult(self.client_id, self._params, self._n, self._delay_s)

    def evaluate(self, aggregated_params: Any) -> EvalResult:
        return EvalResult(self.client_id, {"loss": 1.0}, self._n, 0.0)


def _make_cohort() -> Tuple[List[PacedStub], Any]:
    rng = np.random.default_rng(0)
    template = {"w": jnp.zeros((N_PARAMS,), jnp.float32)}
    clients = [
        PacedStub(
            cid,
            {"w": jnp.asarray(rng.standard_normal(N_PARAMS), jnp.float32)},
            DELAYS[cid],
            N_EXAMPLES[cid],
        )
        for cid in sorted(DELAYS)
    ]
    return clients, template


def _chaos_plan() -> FaultPlan:
    """Five fault kinds plus checkpoint sabotage across rounds 1-4."""
    return FaultPlan(
        [
            FaultSpec("crash", "c0", 1),
            FaultSpec("slow", "c1", 2, delay_s=0.15),
            FaultSpec("corrupt_frame", "c2", 2),
            FaultSpec("hang", "c1", 3, delay_s=0.2),
            FaultSpec("revocation", "c0", 4),
            FaultSpec("corrupt_checkpoint", "s", 4),
        ],
        seed=7,
    )


def _toy_scheduler(n_clients: int = 3, n_vms: int = 3) -> DynamicScheduler:
    providers = [Provider("p0", 0.01), Provider("p1", 0.02)]
    regions = [Region("r0", "p0"), Region("r1", "p1")]
    vms = [
        VMType(
            vm_id=f"vm{i}",
            name=f"t{i}",
            provider="p0" if i % 2 == 0 else "p1",
            region="r0" if i % 2 == 0 else "r1",
            vcpus=4,
            gpus=0,
            ram_gb=16,
            cost_on_demand_hour=1.0 + i,
            cost_spot_hour=(1.0 + i) * 0.3,
        )
        for i in range(n_vms)
    ]
    env = CloudEnvironment(providers, regions, vms)
    env.sl_inst = {v.vm_id: 1.0 for v in vms}
    env.sl_comm = {("r0", "r0"): 1.0, ("r0", "r1"): 2.0, ("r1", "r1"): 1.0}
    app = FLApplication(
        name="chaos-bench",
        clients=[ClientSpec(f"c{i}", train_bl=100.0, test_bl=10.0) for i in range(n_clients)],
        messages=MessageSizes(0.1, 0.1, 0.1, 1e-6),
        n_rounds=5,
        train_comm_bl=5.0,
        test_comm_bl=1.0,
        aggreg_bl=1.0,
    )
    return DynamicScheduler(CostModel(env, app, 0.5))


def _timed_rounds(driver: Any, rounds: int) -> List[float]:
    """Per-round wall times from ONE ``run(rounds)`` call — the driver
    numbers rounds 1..n per call, and the fault plan targets absolute
    round indices, so the whole horizon must be a single run."""
    with driver:
        result = driver.run(rounds)
    return [
        r.train_time_s + r.eval_time_s + r.agg_time_s + r.checkpoint_time_s
        for r in result.rounds
    ]


def _rounds_lost(trace: List[Any], rounds: int) -> int:
    """Rounds whose fold lost cohort weight despite recovery."""
    expected = float(sum(N_EXAMPLES.values()))
    sums: Dict[int, float] = {}
    for e in trace:
        if isinstance(e, UpdateFolded):
            sums[e.round_idx] = sums.get(e.round_idx, 0.0) + e.weight
    return sum(1 for r in range(1, rounds + 1) if sums.get(r, 0.0) < expected)


def run_soak(
    rounds: int, tmp_root: str, trace_out: Optional[str] = None
) -> Dict[str, Any]:
    import os

    # --- fault-free baseline ---
    clients, template = _make_cohort()
    base = Experiment().transport(reply_timeout_s=60.0).serve(clients, template)
    base_times = _timed_rounds(base, rounds)

    # --- chaos run: every hardening layer live ---
    plan = _chaos_plan()
    clients, template = _make_cohort()
    server_ckpt = ServerCheckpointManager(
        os.path.join(tmp_root, "server_local"),
        os.path.join(tmp_root, "server_remote"),
        interval_rounds=1,
        keep_last=3,
    )
    client_ckpts = {
        cid: ClientCheckpointManager(os.path.join(tmp_root, f"ckpt_{cid}"))
        for cid in DELAYS
    }
    placement = {t: Assignment("vm0", "spot") for t in ["s", *DELAYS]}
    driver = Experiment().chaos(plan).transport(
        reply_timeout_s=60.0, heartbeat_interval_s=0.05
    ).serve(
        clients,
        template,
        max_rerequests=2,
        scheduler=_toy_scheduler(),
        placement=placement,
        server_ckpt=server_ckpt,
        client_ckpts=client_ckpts,
    )
    chaos_times = _timed_rounds(driver, rounds)

    pairing = verify_fault_pairing(plan, driver.trace)
    unpaired = [k for k, v in pairing.items() if v == "unpaired"]
    replaced = [e for e in driver.trace if isinstance(e, VMReplaced)]

    n_faults = len(plan.faults)
    overhead_s = max(sum(chaos_times) - sum(base_times), 0.0)
    entry = {
        "n_clients": len(DELAYS),
        "n_params": N_PARAMS,
        "rounds": rounds,
        "n_faults": n_faults,
        "fault_kinds": sorted(plan.kinds),
        "fault_free_round_s": round(statistics.median(base_times), 6),
        "chaos_round_s": round(statistics.median(chaos_times), 6),
        "recovery_overhead_s": round(overhead_s, 6),
        "mttr_s": round(overhead_s / n_faults, 6),
        "rounds_lost": _rounds_lost(driver.trace, rounds),
        "vm_replacements": len(replaced),
        "fault_pairing": {" ".join(map(str, k)): v for k, v in pairing.items()},
        "unpaired_faults": len(unpaired),
    }
    print(
        f"[chaos] {rounds} rounds x{len(DELAYS)}: "
        f"fault_free={statistics.median(base_times)*1e3:.1f}ms/round "
        f"chaos={statistics.median(chaos_times)*1e3:.1f}ms/round "
        f"mttr={entry['mttr_s']*1e3:.0f}ms over {n_faults} faults, "
        f"rounds_lost={entry['rounds_lost']}, "
        f"replacements={len(replaced)}, unpaired={len(unpaired)}",
        file=sys.stderr,
    )

    if trace_out:
        events = [
            {"type": type(e).__name__, **dataclasses.asdict(e)}
            for e in driver.trace
        ]
        with open(trace_out, "w") as f:
            json.dump(events, f, indent=2, default=str)
        print(f"[chaos] wrote {trace_out} ({len(events)} events)", file=sys.stderr)
    return entry


def run_grid(quick: bool = False, trace_out: Optional[str] = None) -> Dict[str, Any]:
    import tempfile

    rounds = ROUNDS_QUICK if quick else ROUNDS_FULL
    with tempfile.TemporaryDirectory() as tmp:
        entry = run_soak(rounds, tmp, trace_out=trace_out)
    return {
        "backend": jax.default_backend(),
        "grid": "quick" if quick else "full",
        "entries": [entry],
    }


def bench_chaos() -> List[Row]:
    """run.py-compatible rows (quick grid)."""
    report = run_grid(quick=True)
    rows: List[Row] = []
    for e in report["entries"]:
        rows.append((
            f"chaos_soak_{e['n_clients']}x{e['rounds']}r",
            e["chaos_round_s"] * 1e6,
            f"fault_free_us={e['fault_free_round_s']*1e6:.0f};"
            f"mttr_ms={e['mttr_s']*1e3:.0f};"
            f"rounds_lost={e['rounds_lost']};"
            f"unpaired={e['unpaired_faults']}",
        ))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="small grid (CI smoke)")
    ap.add_argument("--out", default="BENCH_chaos.json")
    ap.add_argument("--trace-out", default=None,
                    help="also dump the chaos run's event trace as JSON")
    args = ap.parse_args()

    report = run_grid(quick=args.quick, trace_out=args.trace_out)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"[chaos] wrote {args.out}", file=sys.stderr)

    print("name,us_per_call,derived")
    for e in report["entries"]:
        print(
            f"chaos_soak_{e['n_clients']}x{e['rounds']}r,"
            f"{e['chaos_round_s']*1e6:.1f},"
            f"mttr_ms={e['mttr_s']*1e3:.0f};"
            f"rounds_lost={e['rounds_lost']};"
            f"unpaired={e['unpaired_faults']}"
        )


if __name__ == "__main__":
    main()
