"""Kernel micro-benchmarks: wall time of the jnp oracle on CPU (the Pallas
kernels run in interpret mode here — their timing is only meaningful on a
real TPU), plus derived arithmetic-intensity numbers used by §Roofline."""
from __future__ import annotations

import sys
import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref

Row = Tuple[str, float, str]


def _time_fn(fn, *args, iters=5) -> float:
    """Median wall time of fn(*args) in microseconds.

    One warmup call (jit compile) blocked on the whole result —
    `jax.block_until_ready` traverses tuples/pytrees natively.
    """
    jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.monotonic()
        jax.block_until_ready(fn(*args))
        times.append(time.monotonic() - t0)
    return float(np.median(times)) * 1e6


def bench_kernels() -> List[Row]:
    rows: List[Row] = []
    rng = np.random.default_rng(0)

    # fedavg_reduce: 8 clients x 4M params
    x = jnp.asarray(rng.standard_normal((8, 4_000_000)).astype(np.float32))
    w = jnp.ones((8,))
    f = jax.jit(ref.fedavg_reduce_ref)
    us = _time_fn(f, x, w)
    bytes_moved = x.nbytes + x.shape[1] * 4
    ai = (2 * x.size) / bytes_moved
    rows.append(("kernel_fedavg_reduce_8x4M", us, f"arith_intensity={ai:.3f}"))
    print(f"[kernels] fedavg_reduce: {us:.0f} us/call, AI={ai:.3f} flop/byte "
          f"(memory-bound reduce)", file=sys.stderr)

    # flash attention oracle: 1k seq
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 1024, 8, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 1024, 8, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 1024, 8, 64), jnp.float32)
    f = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v))
    us = _time_fn(f, q, k, v)
    flops = 4 * 1024 * 1024 * 8 * 64  # qk + pv
    rows.append(("kernel_flash_attention_1k", us, f"gflops={flops/1e9:.2f}"))
    print(f"[kernels] flash_attention 1k: {us:.0f} us/call", file=sys.stderr)

    # ssd scan oracle
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    B, L, H, P, N = 2, 512, 8, 64, 64
    xs = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, L, N))
    Cm = jax.random.normal(ks[4], (B, L, N))
    f = jax.jit(lambda *a: ref.ssd_scan_ref(*a, chunk=128))
    us = _time_fn(f, xs, dt, A, Bm, Cm)
    rows.append(("kernel_ssd_scan_512", us, f"chunk=128"))
    print(f"[kernels] ssd_scan 512: {us:.0f} us/call", file=sys.stderr)
    return rows
