"""§Roofline table: read the dry-run sweep results (results/*.jsonl) and
emit one row per (arch x shape x mesh) with the three roofline terms."""
from __future__ import annotations

import json
import os
import sys
from typing import List, Tuple

Row = Tuple[str, float, str]

RESULT_FILES = (
    "results/dryrun_single_pod.jsonl",
    "results/dryrun_multi_pod.jsonl",
)


def bench_roofline_table() -> List[Row]:
    rows: List[Row] = []
    found = False
    for path in RESULT_FILES:
        if not os.path.exists(path):
            continue
        found = True
        with open(path) as f:
            for line in f:
                r = json.loads(line)
                name = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
                dominant = r["dominant"]
                derived = (
                    f"compute_ms={r['compute_s']*1e3:.2f};"
                    f"memory_ms={r['memory_s']*1e3:.2f};"
                    f"collective_ms={r['collective_s']*1e3:.2f};"
                    f"dominant={dominant};"
                    f"fits={r.get('fits')}"
                )
                rows.append((name, float(r.get("compile_s", 0)) * 1e6, derived))
                print(
                    f"[roofline] {r['arch']:22s} {r['shape']:12s} {r['mesh']:8s} "
                    f"c={r['compute_s']*1e3:8.2f}ms m={r['memory_s']*1e3:8.2f}ms "
                    f"coll={r['collective_s']*1e3:8.2f}ms -> {dominant:10s} "
                    f"useful={100*(r.get('useful_ratio') or 0):.0f}% "
                    f"peak={r.get('peak_memory_per_chip', 0)/1e9:.1f}GB",
                    file=sys.stderr,
                )
    if not found:
        print("[roofline] no dry-run results found — run "
              "`python -m repro.launch.dryrun --all --json results/dryrun_single_pod.jsonl`",
              file=sys.stderr)
    return rows
