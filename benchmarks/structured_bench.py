"""Structured-update benchmark: federated LoRA wire footprint + parity.

What does shipping named parameter *groups* instead of the full pytree
buy?  Two measurements:

* ``zoo_wire`` — the adapter-FL wire win on a real model-zoo config
  (olmo-1b reduced, LoRA rank 2 on wq/wk/wv/wo): the serialized
  ``c_msg_train`` frame a silo puts on the inter-cloud link when the
  ``{"adapters": ".lora_"}`` schema is active, against the dense fp32
  frame for the same (injected) model.  The tentpole acceptance
  number: ``wire_reduction_vs_fp32 >= 50`` (``wire_ratio_ge_50x``).
  Encode+serialize compute is timed too — the structured path must not
  buy its bytes with pathological CPU time.

* ``lora_parity`` — a short federated-LoRA convergence run (frozen
  linear base + rank-1 adapters, masked optimizer) through BOTH the
  in-process ``AsyncFLServer`` and the loopback-socket
  ``LiveRoundDriver``, same schema, deterministic reply order: final
  params must match (``sim_live_params_match``), the control-plane
  traces must carry the same event sequence modulo timestamps
  (``sim_live_trace_match``), and the per-group ``c_msg_train`` byte
  accounting must agree between the simulated and measured logs
  (``sim_live_bytes_match``).

Writes BENCH_structured.json (or --out) and prints
``name,us_per_call,derived`` CSV rows like benchmarks/run.py.

Usage:
  PYTHONPATH=src python benchmarks/structured_bench.py [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.serializer import serialize_pytree
from repro.configs import get_config
from repro.federated.agg_engine import plan_for
from repro.federated.async_server import AsyncFLServer, DeterministicSchedule
from repro.federated.client import FLClient
from repro.federated.compression import (
    StructuredCompressor,
    serialize_structured,
)
from repro.federated.transport import LiveRoundDriver, ThreadWorkerPool
from repro.models.api import get_model
from repro.models.fl_models import (
    LoRAConfig,
    inject_lora,
    lora_adapter_schema,
    lora_effective,
)
from repro.optim import make_optimizer, masked

Row = Tuple[str, float, str]

ZOO_ARCH = "olmo-1b"
LORA_RANK = 2
ROUNDS = 8
QUICK_ROUNDS = 4
ENCODE_REPS = 6


# ---------------------------------------------------------------------------
# Part 1: model-zoo adapter wire footprint
# ---------------------------------------------------------------------------

def bench_zoo_wire(arch: str = ZOO_ARCH, rank: int = LORA_RANK) -> Dict[str, Any]:
    """Dense fp32 vs adapters-only structured c_msg_train bytes on a
    reduced zoo config with injected LoRA factors."""
    cfg = get_config(arch).reduced().with_lora(rank)
    lora = LoRAConfig(rank=cfg.lora_rank, alpha=cfg.lora_alpha,
                      targets=cfg.lora_targets)
    params = get_model(cfg).init(jax.random.PRNGKey(0))
    params = inject_lora(params, jax.random.PRNGKey(1), lora)

    # A post-training local state: only the adapters moved (the masked
    # optimizer freezes everything else), which is what a client ships.
    rng = np.random.default_rng(0)
    local = jax.tree_util.tree_map_with_path(
        lambda path, leaf: (
            leaf + jnp.asarray(rng.standard_normal(leaf.shape) * 0.01,
                               leaf.dtype)
            if ".lora_" in jax.tree_util.keystr(path) else leaf
        ),
        params,
    )

    schema = lora_adapter_schema()
    enc = StructuredCompressor(schema, None)
    update = enc.encode(params, local, base_round=1)
    wire = serialize_structured(update)

    dense_frame = serialize_pytree(local)
    plan = plan_for(params)
    total_elems = plan.total_elems
    adapter_elems = sum(
        int(np.asarray(p).size) for _, p in update.groups
    )
    dense_fp32_bytes = total_elems * 4

    times: List[float] = []
    for _ in range(ENCODE_REPS):
        t0 = time.perf_counter()
        serialize_structured(enc.encode(params, local, base_round=1))
        times.append(time.perf_counter() - t0)
    encode_s = statistics.median(times)

    entry = {
        "arch": cfg.name,
        "lora_rank": rank,
        "lora_targets": list(cfg.lora_targets),
        "total_elems": int(total_elems),
        "adapter_elems": int(adapter_elems),
        "elem_reduction": round(total_elems / adapter_elems, 1),
        "wire_bytes_structured": len(wire),
        "wire_bytes_dense_frame": len(dense_frame),
        "dense_fp32_bytes": int(dense_fp32_bytes),
        "wire_reduction_vs_fp32": round(dense_fp32_bytes / len(wire), 1),
        "group_wire_bytes": update.group_wire_bytes(),
        "group_dense_bytes": update.group_dense_bytes(),
        "encode_s": round(encode_s, 6),
        "wire_ratio_ge_50x": dense_fp32_bytes / len(wire) >= 50.0,
    }
    print(
        f"[structured] {cfg.name} rank={rank}: adapters "
        f"{adapter_elems}/{total_elems} elems, wire "
        f"{len(wire)/1e3:.1f}kB vs dense {dense_fp32_bytes/1e3:.0f}kB "
        f"({entry['wire_reduction_vs_fp32']}x, encode="
        f"{encode_s*1e3:.1f}ms)",
        file=sys.stderr,
    )
    return entry


# ---------------------------------------------------------------------------
# Part 2: sim-vs-live federated LoRA parity
# ---------------------------------------------------------------------------

LORA_TOY = LoRAConfig(rank=1, alpha=1.0, targets=("w",))


class _Silo:
    def __init__(self, x: Any, y: Any) -> None:
        self.x, self.y = x, y

    def batches(self, batch_size: int, split: str = "train"):
        for i in range(0, len(self.x), batch_size):
            yield (self.x[i:i + batch_size], self.y[i:i + batch_size])


class _ChainedClient(FLClient):
    """FLClient whose c_msg_train order is forced by a semaphore chain —
    live socket arrivals then match the simulator's client order."""

    def __init__(self, *args: Any, **kwargs: Any) -> None:
        super().__init__(*args, **kwargs)
        self.acquire_sem: Optional[threading.Semaphore] = None
        self.release_sem: Optional[threading.Semaphore] = None

    def train(self, global_params: Any) -> Any:
        if self.acquire_sem is not None:
            assert self.acquire_sem.acquire(timeout=60.0)
            time.sleep(0.05)  # let the releaser's reply hit the wire first
        result = super().train(global_params)
        if self.release_sem is not None:
            self.release_sem.release()
        return result


def _lora_loss(params: Any, batch: Any) -> jnp.ndarray:
    x, y = batch
    eff = lora_effective(params, LORA_TOY)
    pred = (x @ eff["fc"]["w"])[:, 0]
    return jnp.mean((pred - y) ** 2)


def _lora_cohort(chained: bool, seed: int = 7) -> List[FLClient]:
    rng = np.random.default_rng(seed)
    w_true = rng.standard_normal(3)
    clients: List[FLClient] = []
    for i in range(2):
        n = 24
        x = rng.standard_normal((n, 3))
        y = x @ w_true + 0.05 * rng.standard_normal(n)
        clients.append(
            _ChainedClient(
                f"c{i}",
                _Silo(jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.float32)),
                _lora_loss,
                masked(make_optimizer("sgdm", 1e-2), ".lora_"),
                batch_size=8,
            )
        )
    if chained:
        sem = threading.Semaphore(0)
        clients[0].release_sem = sem
        clients[1].acquire_sem = sem
    return clients


def _lora_init() -> Any:
    base = {"fc": {"w": jnp.zeros((3, 1), jnp.float32)}}
    return inject_lora(base, jax.random.PRNGKey(0), LORA_TOY)


def _trace_signature(trace: List[Any]) -> List[Tuple[Any, ...]]:
    return [
        (type(e).__name__, getattr(e, "round_idx", None),
         getattr(e, "task", None), getattr(e, "attempt", None))
        for e in trace
    ]


def bench_lora_parity(rounds: int = ROUNDS) -> Dict[str, Any]:
    schema = lora_adapter_schema()
    init = _lora_init()

    server = AsyncFLServer(
        _lora_cohort(chained=False),
        init,
        schedule=DeterministicSchedule(0.0),
        schema=schema,
        measure_round_messages=True,
    )
    t0 = time.perf_counter()
    sim = server.run(rounds)
    sim_s = time.perf_counter() - t0

    driver = LiveRoundDriver(
        ThreadWorkerPool(_lora_cohort(chained=True), init, schema=schema),
        init,
        reply_timeout_s=120.0,
        schema=schema,
        measure_round_messages=True,
    )
    t0 = time.perf_counter()
    with driver:
        live = driver.run(rounds)
    live_s = time.perf_counter() - t0

    sim_w = np.asarray(lora_effective(sim.final_params, LORA_TOY)["fc"]["w"])
    live_w = np.asarray(lora_effective(live.final_params, LORA_TOY)["fc"]["w"])
    max_diff = float(np.max(np.abs(sim_w - live_w)))

    sim_log = sim.rounds[-1].message_log
    live_log = driver.message_logs[-1]
    assert sim_log is not None
    bytes_match = (
        sim_log.group_wire_bytes == live_log.group_wire_bytes
        and sim_log.c_msg_train_bytes == live_log.c_msg_train_bytes
    )
    trace_match = (
        _trace_signature(server.bus.trace) == _trace_signature(driver.trace)
    )

    entry = {
        "rounds": rounds,
        "final_loss_sim": round(float(sim.rounds[-1].metrics["loss"]), 6),
        "final_loss_live": round(float(live.rounds[-1].metrics["loss"]), 6),
        "max_param_diff": max_diff,
        "codec": live_log.codec,
        "c_train_bytes": live_log.c_msg_train_bytes,
        "c_train_dense_bytes": live_log.c_msg_train_dense_bytes,
        "group_wire_bytes": dict(live_log.group_wire_bytes or {}),
        "group_dense_bytes": dict(live_log.group_dense_bytes or {}),
        "sim_round_s": round(sim_s / rounds, 6),
        "live_round_s": round(live_s / rounds, 6),
        "sim_live_params_match": max_diff < 1e-5,
        "sim_live_trace_match": trace_match,
        "sim_live_bytes_match": bytes_match,
    }
    print(
        f"[structured] lora parity over {rounds} rounds: "
        f"loss sim={entry['final_loss_sim']} live={entry['final_loss_live']} "
        f"max|dw|={max_diff:.2e} trace_match={trace_match} "
        f"bytes_match={bytes_match} wire={live_log.c_msg_train_bytes}B "
        f"({live_log.codec})",
        file=sys.stderr,
    )
    return entry


# ---------------------------------------------------------------------------
# Harness plumbing
# ---------------------------------------------------------------------------

def run_grid(quick: bool = False, rounds: Optional[int] = None) -> Dict[str, Any]:
    r = rounds if rounds is not None else (QUICK_ROUNDS if quick else ROUNDS)
    return {
        "backend": jax.default_backend(),
        "grid": "quick" if quick else "full",
        "zoo_wire": bench_zoo_wire(),
        "lora_parity": bench_lora_parity(rounds=r),
    }


def bench_structured() -> List[Row]:
    """run.py-compatible rows (quick grid)."""
    return _rows(run_grid(quick=True))


def _rows(report: Dict[str, Any]) -> List[Row]:
    z = report["zoo_wire"]
    p = report["lora_parity"]
    return [
        (
            f"structured_zoo_{z['arch']}_r{z['lora_rank']}",
            z["encode_s"] * 1e6,
            f"wire_b={z['wire_bytes_structured']};"
            f"reduction={z['wire_reduction_vs_fp32']};"
            f"ge_50x={z['wire_ratio_ge_50x']}",
        ),
        (
            "structured_lora_parity",
            p["live_round_s"] * 1e6,
            f"params_match={p['sim_live_params_match']};"
            f"trace_match={p['sim_live_trace_match']};"
            f"bytes_match={p['sim_live_bytes_match']};"
            f"wire_b={p['c_train_bytes']}",
        ),
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="small grid (CI smoke)")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--out", default="BENCH_structured.json")
    args = ap.parse_args()

    report = run_grid(quick=args.quick, rounds=args.rounds)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"[structured] wrote {args.out}", file=sys.stderr)

    print("name,us_per_call,derived")
    for name, us, derived in _rows(report):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
