"""Socket-transport overhead benchmark.

What does moving a §3 round onto real sockets cost over the in-process
engine?  Same cohort, same fold engine, same bus vocabulary — the only
difference is that ``LiveRoundDriver`` serializes every weight message
(msgpack + raw buffers) and moves it through loopback TCP to thread
workers, while ``AsyncFLServer`` hands pytrees over in memory.

Measures, per param count:

* ``live_round_s``  — median wall-clock round of a loopback
  ``LiveRoundDriver`` over N instant stub workers (serialize + 2x wire
  transfer per silo per phase + deserialize + fold);
* ``inproc_round_s`` — median round of the in-process ``AsyncFLServer``
  on the same stub cohort (InstantSchedule);
* the derived per-round transport overhead and effective wire
  throughput (payload bytes moved / extra time paid).

Writes BENCH_transport.json (or --out) and prints
``name,us_per_call,derived`` CSV rows like benchmarks/run.py.

Usage:
  PYTHONPATH=src python benchmarks/transport_bench.py [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
import time
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.federated.async_server import AsyncFLServer, InstantSchedule
from repro.federated.client import ClientResult, EvalResult
from repro.federated.transport import LiveRoundDriver, ThreadWorkerPool

Row = Tuple[str, float, str]

N_CLIENTS = 4
ROUNDS = 8
FULL_PARAMS = [250_000, 1_000_000]
QUICK_PARAMS = [250_000]


class StubClient:
    """Instant duck-typed FLClient: fixed params, no training compute —
    isolates the transport/serialization cost from the learning cost."""

    def __init__(self, client_id: str, params: Any, n_samples: int) -> None:
        self.client_id = client_id
        self._params = params
        self._n = n_samples

    def train(self, global_params: Any) -> ClientResult:
        return ClientResult(self.client_id, self._params, self._n, 0.0)

    def evaluate(self, aggregated_params: Any) -> EvalResult:
        return EvalResult(self.client_id, {"loss": 1.0}, self._n, 0.0)


def _make_cohort(n_clients: int, n_params: int) -> Tuple[List[StubClient], Any]:
    rng = np.random.default_rng(0)
    template = {
        "w": jnp.zeros((n_params,), jnp.float32),
    }
    clients = [
        StubClient(
            f"c{i}",
            {"w": jnp.asarray(rng.standard_normal(n_params), jnp.float32)},
            10 * (i + 1),
        )
        for i in range(n_clients)
    ]
    return clients, template


def bench_shape(n_params: int, rounds: int = ROUNDS) -> Dict[str, Any]:
    clients, template = _make_cohort(N_CLIENTS, n_params)

    driver = LiveRoundDriver(
        ThreadWorkerPool(clients, template), template, reply_timeout_s=120.0
    )
    with driver:
        driver.run(1)  # warm: jit traces, worker jit-through, TCP windows
        live_times: List[float] = []
        for _ in range(rounds):
            t0 = time.perf_counter()
            driver.run(1)
            live_times.append(time.perf_counter() - t0)
        log = driver.message_logs[-1]

    server = AsyncFLServer(clients, template, schedule=InstantSchedule())
    server.run(1)  # warm
    inproc_times: List[float] = []
    for _ in range(rounds):
        t0 = time.perf_counter()
        server.run(1)
        inproc_times.append(time.perf_counter() - t0)

    live_s = statistics.median(live_times)
    inproc_s = statistics.median(inproc_times)
    overhead_s = max(live_s - inproc_s, 0.0)
    # Payload actually moved per round: train weights out+back and the
    # aggregate out, per silo, plus the metric replies.
    wire_bytes = log.total_bytes(N_CLIENTS)
    throughput = wire_bytes / overhead_s if overhead_s > 0 else float("inf")
    entry = {
        "n_clients": N_CLIENTS,
        "n_params": n_params,
        "rounds": rounds,
        "live_round_s": round(live_s, 6),
        "inproc_round_s": round(inproc_s, 6),
        "transport_overhead_s": round(overhead_s, 6),
        "wire_bytes_per_round": wire_bytes,
        "effective_throughput_mb_s": (
            round(throughput / 1e6, 1) if overhead_s > 0 else None
        ),
    }
    print(
        f"[transport] P={n_params//1000}k x{N_CLIENTS}: "
        f"inproc={inproc_s*1e3:.1f}ms live={live_s*1e3:.1f}ms "
        f"(+{overhead_s*1e3:.1f}ms for {wire_bytes/1e6:.1f}MB on the wire"
        + (f", {throughput/1e6:.0f}MB/s)" if overhead_s > 0 else ")"),
        file=sys.stderr,
    )
    return entry


def run_grid(quick: bool = False, rounds: int = ROUNDS) -> Dict[str, Any]:
    params = QUICK_PARAMS if quick else FULL_PARAMS
    return {
        "backend": jax.default_backend(),
        "grid": "quick" if quick else "full",
        "entries": [bench_shape(p, rounds=rounds) for p in params],
    }


def bench_transport() -> List[Row]:
    """run.py-compatible rows (quick grid)."""
    report = run_grid(quick=True, rounds=4)
    rows: List[Row] = []
    for e in report["entries"]:
        rows.append((
            f"transport_live_{e['n_clients']}x{e['n_params']//1000}k",
            e["live_round_s"] * 1e6,
            f"inproc_us={e['inproc_round_s']*1e6:.0f};"
            f"wire_mb={e['wire_bytes_per_round']/1e6:.1f};"
            f"throughput_mb_s={e['effective_throughput_mb_s']}",
        ))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="small grid (CI smoke)")
    ap.add_argument("--rounds", type=int, default=ROUNDS)
    ap.add_argument("--out", default="BENCH_transport.json")
    args = ap.parse_args()

    report = run_grid(quick=args.quick, rounds=args.rounds)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"[transport] wrote {args.out}", file=sys.stderr)

    print("name,us_per_call,derived")
    for e in report["entries"]:
        print(
            f"transport_live_{e['n_clients']}x{e['n_params']},"
            f"{e['live_round_s']*1e6:.1f},"
            f"inproc_us={e['inproc_round_s']*1e6:.1f};"
            f"wire_mb={e['wire_bytes_per_round']/1e6:.1f}"
        )


if __name__ == "__main__":
    main()
