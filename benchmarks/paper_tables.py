"""One benchmark function per paper table/figure. Each returns
(name, us_per_call, derived) rows where `derived` is the table's headline
metric, plus a human-readable report printed to stderr.
"""
from __future__ import annotations

import statistics
import sys
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core import (
    SERVER,
    CheckpointPolicy,
    InitialMapping,
    MultiCloudSimulator,
    PreScheduling,
    ProbeResult,
    SimulationConfig,
    TableProbe,
    aws_gcp_environment,
    cloudlab_environment,
    femnist_application,
    shakespeare_application,
    til_application,
    til_application_aws,
)

Row = Tuple[str, float, str]


def _report(msg: str) -> None:
    print(msg, file=sys.stderr)


def _timed(fn):
    t0 = time.monotonic()
    out = fn()
    return out, (time.monotonic() - t0) * 1e6


# ---------------------------------------------------------------------------
# Tables 3 + 4 — Pre-Scheduling slowdowns
# ---------------------------------------------------------------------------

def bench_pre_scheduling() -> List[Row]:
    env = cloudlab_environment()
    published_inst = dict(env.sl_inst)
    published_comm = dict(env.sl_comm)

    # Reconstruct slowdowns from raw probe timings and check they round-trip
    # to the published tables.
    vm_times = {
        vm: ProbeResult(sl * 100.0 * 0.97, sl * 100.0 * 0.03)
        for vm, sl in published_inst.items()
    }
    pair_times = {
        pair: ProbeResult(sl * 8.66 * 2 / 3, sl * 8.66 / 3)
        for pair, sl in published_comm.items()
    }

    def run():
        ps = PreScheduling(env, TableProbe(vm_times, pair_times))
        return ps.run("vm_121", ("cloud_b_apt", "cloud_b_apt"))

    result, us = _timed(run)

    def lookup(pair):
        return result.sl_comm.get(pair, result.sl_comm.get((pair[1], pair[0])))

    err_inst = max(abs(result.sl_inst[v] - published_inst[v]) for v in published_inst)
    err_comm = max(abs(lookup(p) - published_comm[p]) for p in published_comm)
    _report(f"[table3] exec slowdowns: {len(result.sl_inst)} VMs, max err {err_inst:.2e}")
    _report(f"[table4] comm slowdowns: {len(result.sl_comm)} pairs, max err {err_comm:.2e}")
    return [
        ("table3_exec_slowdowns", us, f"max_err={err_inst:.2e}"),
        ("table4_comm_slowdowns", us, f"max_err={err_comm:.2e}"),
    ]


# ---------------------------------------------------------------------------
# §5.4 — Initial Mapping validation on CloudLab
# ---------------------------------------------------------------------------

def bench_initial_mapping() -> List[Row]:
    env = cloudlab_environment()
    app = til_application(n_rounds=10)

    def run():
        return InitialMapping(env, app, alpha=0.5).solve()

    sol, us = _timed(run)
    runtime_min = sol.evaluation.makespan_s * 10 / 60
    # VM cost over FL execution + ~20 min CloudLab preparation (the paper's
    # modeled $15.44 includes VM preparation billing — §5.4 / EXPERIMENTS.md).
    prep_s = 1200.0
    rate = sum(
        env.vm_types[a.vm_id].cost_per_second()
        for a in sol.placement.values()
    )
    cost_with_prep = rate * (sol.evaluation.makespan_s * 10 + prep_s) + sol.evaluation.comm_costs * 10
    _report(
        f"[§5.4] placement: server={sol.vm_of(SERVER)} clients="
        f"{[sol.vm_of(c.client_id) for c in app.clients]}"
    )
    _report(
        f"[§5.4] modeled runtime {runtime_min:.1f} min (paper 22:38); "
        f"modeled cost ${cost_with_prep:.2f} incl. prep (paper $15.44)"
    )
    return [
        ("s5_4_initial_mapping_runtime_min", us, f"{runtime_min:.2f}_vs_22.63"),
        ("s5_4_initial_mapping_cost_usd", us, f"{cost_with_prep:.2f}_vs_15.44"),
    ]


# ---------------------------------------------------------------------------
# §5.5 / Fig. 2 — checkpoint overhead
# ---------------------------------------------------------------------------

def bench_checkpoint_overhead() -> List[Row]:
    env = cloudlab_environment()
    app = til_application(n_rounds=80)  # longer run as in §5.5
    base = MultiCloudSimulator(
        env, app, SimulationConfig(k_r=None, vm_startup_s=1200.0)
    ).run()

    rows: List[Row] = []
    _report(f"[fig2] no-checkpoint FL time {base.fl_exec_time_s/60:.1f} min")
    for interval in (10, 20, 30, 40):
        def run(iv=interval):
            return MultiCloudSimulator(
                env, app,
                SimulationConfig(
                    k_r=None, vm_startup_s=1200.0,
                    checkpoint=CheckpointPolicy(server_interval_rounds=iv),
                ),
            ).run()

        res, us = _timed(run)
        ov = (res.fl_exec_time_s - base.fl_exec_time_s) / base.fl_exec_time_s * 100
        _report(f"[fig2] X={interval}: overhead {ov:.2f}% (paper 6.29-7.55%)")
        rows.append((f"fig2_server_ckpt_X{interval}", us, f"overhead={ov:.2f}%"))

    # client-side checkpoint every round (paper: 2.17%)
    def run_client():
        return MultiCloudSimulator(
            env, app,
            SimulationConfig(
                k_r=None, vm_startup_s=1200.0,
                checkpoint=CheckpointPolicy(server_interval_rounds=0, client_every_round=True),
            ),
        ).run()

    res, us = _timed(run_client)
    ov = (res.fl_exec_time_s - base.fl_exec_time_s) / base.fl_exec_time_s * 100
    _report(f"[§5.5] client ckpt overhead {ov:.2f}% (paper 2.17%)")
    rows.append(("s5_5_client_ckpt", us, f"overhead={ov:.2f}%"))
    return rows


# ---------------------------------------------------------------------------
# Tables 5-8 — failure simulation
# ---------------------------------------------------------------------------

def _failure_grid(env, app, k_rs, remove_revoked, vm_startup_s, seeds=(0, 1, 2)) -> List[Tuple]:
    out = []
    for scenario, (sm, cm) in (
        ("all_spot", ("spot", "spot")),
        ("od_server", ("on_demand", "spot")),
    ):
        for kr in k_rs:
            runs = [
                MultiCloudSimulator(
                    env, app,
                    SimulationConfig(
                        server_market=sm, client_market=cm, k_r=kr, seed=s,
                        vm_startup_s=vm_startup_s,
                        checkpoint=CheckpointPolicy(server_interval_rounds=10),
                        remove_revoked=remove_revoked,
                    ),
                ).run()
                for s in seeds
            ]
            out.append(
                (
                    scenario,
                    kr,
                    statistics.mean(r.n_revocations for r in runs),
                    statistics.mean(r.total_time_s for r in runs),
                    statistics.mean(r.total_cost for r in runs),
                )
            )
    return out


def bench_failure_til() -> List[Row]:
    env = cloudlab_environment()
    app = til_application(n_rounds=73)  # ~3 h on-demand baseline (§5.6.1)
    rows: List[Row] = []
    for name, remove in (("table5_change_vm", True), ("table6_same_vm", False)):
        t0 = time.monotonic()
        grid = _failure_grid(env, app, (7200, 14400), remove, 1200.0)
        us = (time.monotonic() - t0) * 1e6
        for scenario, kr, rev, t, c in grid:
            _report(
                f"[{name}] {scenario} k_r={kr}: revoc={rev:.2f} "
                f"time={t/3600:.2f}h cost=${c:.2f}"
            )
            rows.append(
                (f"{name}_{scenario}_kr{kr}", us / 4, f"revoc={rev:.2f};time_h={t/3600:.2f};cost={c:.2f}")
            )
    return rows


def bench_failure_benchmarks() -> List[Row]:
    env = cloudlab_environment()
    rows: List[Row] = []
    for name, app in (
        ("table7_shakespeare", shakespeare_application(n_rounds=20)),
        ("table8_femnist", femnist_application(n_rounds=100)),
    ):
        t0 = time.monotonic()
        grid = _failure_grid(env, app, (3600, 7200), remove_revoked=False, vm_startup_s=1200.0)
        us = (time.monotonic() - t0) * 1e6
        for scenario, kr, rev, t, c in grid:
            _report(
                f"[{name}] {scenario} k_r={kr}: revoc={rev:.2f} "
                f"time={t/3600:.2f}h cost=${c:.2f}"
            )
            rows.append(
                (f"{name}_{scenario}_kr{kr}", us / 4, f"revoc={rev:.2f};time_h={t/3600:.2f};cost={c:.2f}")
            )
    return rows


# ---------------------------------------------------------------------------
# §5.7 — AWS/GCP proof of concept
# ---------------------------------------------------------------------------

def bench_poc_aws_gcp() -> List[Row]:
    env = aws_gcp_environment()
    app = til_application_aws(n_rounds=10)

    def run_od():
        return MultiCloudSimulator(env, app, SimulationConfig(k_r=None, vm_startup_s=154.0)).run()

    od, us_od = _timed(run_od)

    t0 = time.monotonic()
    spots = [
        MultiCloudSimulator(
            env, app,
            SimulationConfig(
                server_market="spot", client_market="spot", k_r=7200, seed=s,
                vm_startup_s=154.0,
                checkpoint=CheckpointPolicy(server_interval_rounds=10),
            ),
        ).run()
        for s in range(5)
    ]
    us_spot = (time.monotonic() - t0) * 1e6
    spot_cost = statistics.mean(r.total_cost for r in spots)
    spot_time = statistics.mean(r.total_time_s for r in spots)
    savings = (1 - spot_cost / od.total_cost) * 100
    slowdown = (spot_time / od.total_time_s - 1) * 100
    _report(
        f"[§5.7] on-demand {od.total_time_s/3600:.2f}h ${od.total_cost:.2f} "
        f"(paper 2:00:18 $3.28)"
    )
    _report(
        f"[§5.7] spot {spot_time/3600:.2f}h ${spot_cost:.2f} -> "
        f"savings {savings:.1f}% time +{slowdown:.1f}% (paper 56.9% / +5.4%)"
    )
    return [
        ("s5_7_poc_on_demand_cost", us_od, f"{od.total_cost:.2f}_vs_3.28"),
        ("s5_7_poc_spot_savings_pct", us_spot, f"{savings:.1f}_vs_56.9"),
    ]
