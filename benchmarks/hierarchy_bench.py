"""Hierarchy benchmark: fold throughput + rounds/s, flat vs region trees.

What does the two-level aggregation hierarchy
(``repro.federated.hierarchy``) cost at cohort scale?  The regional
engines each fold their cohort into a padded fp32 accumulator and
export a :class:`~repro.federated.agg_engine.PartialSum`; the parent
folds R partials with one donated add each.  Per grid point
(n_clients x tree shape):

* ``us_per_client`` — wall time of one full round's fold divided by the
  cohort size: the per-update cost of the hot path (``add`` into the
  streaming accumulator + the parent's ``fold_partial`` amortized);
* ``rounds_per_s`` — 1 / round fold time: how fast the server side can
  turn rounds if the wire were free;
* ``overhead_vs_flat`` — tree fold time / flat fold time at the same
  cohort size (the price of the extra partial hop, which buys the
  regional fan-in);
* ``vs_flat8_per_client`` — per-client cost relative to the flat
  8-silo baseline (the paper's cross-silo scale).  The tentpole
  acceptance: at 10k clients this stays within 2x, i.e. the hierarchy
  keeps per-update cost flat while the population grows 3 orders of
  magnitude.

A second section times the *engine* path — ``HierarchyCoordinator.
fold_round`` (real per-client FoldEvents, carry-over bookkeeping, bus
summaries) against a flat ``AsyncRoundEngine`` — at a moderate cohort,
so the coordinator's per-round overhead is visible separately from the
raw fold arithmetic.

Writes BENCH_hierarchy.json (or --out) and prints
``name,us_per_call,derived`` CSV rows like benchmarks/run.py.

Usage:
  PYTHONPATH=src python benchmarks/hierarchy_bench.py [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.events import NULL_BUS
from repro.federated.agg_engine import AggregationEngine
from repro.federated.async_server import AsyncRoundEngine, InstantSchedule
from repro.federated.client import ClientResult
from repro.federated.hierarchy import HierarchyCoordinator, partition_regions

Row = Tuple[str, float, str]

N_PARAMS = 8192          # one dense layer's worth — fold cost is O(L) per add
UPDATE_POOL = 64         # distinct simulated updates cycled over the cohort
REPEATS = 5
FULL_COHORTS = [1_000, 10_000]
QUICK_COHORTS = [1_000]
TREES = [1, 4, 16]       # 1 == flat (no regional hop)
ENGINE_COHORT = 512      # coordinator-path benchmark size


def _update_pool(n: int, n_params: int, seed: int = 0) -> List[Any]:
    """Pre-built simulated client updates (two-leaf tree, L total)."""
    rng = np.random.default_rng(seed)
    k = n_params // 2
    return [
        {
            "w": jnp.asarray(rng.standard_normal(k), jnp.float32),
            "b": jnp.asarray(rng.standard_normal(n_params - k), jnp.float32),
        }
        for _ in range(n)
    ]


def _base(n_params: int) -> Any:
    k = n_params // 2
    return {
        "w": jnp.zeros((k,), jnp.float32),
        "b": jnp.zeros((n_params - k,), jnp.float32),
    }


def fold_once(
    engine: AggregationEngine,
    base: Any,
    pool: List[Any],
    weights: List[float],
    n_clients: int,
    regions: int,
) -> Any:
    """One round's fold: flat (regions == 1) or two-level tree."""
    if regions == 1:
        agg = engine.streaming(base=base, base_round=0)
        for i in range(n_clients):
            agg.add(pool[i % len(pool)], weights[i])
        return agg.result()
    parent = engine.streaming(base=base, base_round=0)
    for r in range(regions):
        regional = engine.streaming(base=base, base_round=0)
        for i in range(r, n_clients, regions):
            regional.add(pool[i % len(pool)], weights[i])
        parent.fold_partial(regional.export_partial(f"region{r}"))
    return parent.result()


def bench_fold_tree(
    n_clients: int,
    regions: int,
    flat8_us_per_client: Optional[float] = None,
    repeats: int = REPEATS,
) -> Dict[str, Any]:
    """Measured fold wall time for one (cohort, tree-shape) grid point."""
    engine = AggregationEngine()
    base = _base(N_PARAMS)
    pool = _update_pool(min(n_clients, UPDATE_POOL), N_PARAMS)
    rng = np.random.default_rng(1)
    weights = [float(w) for w in rng.integers(1, 16, size=n_clients)]

    jax.block_until_ready(
        jax.tree.leaves(fold_once(engine, base, pool, weights, n_clients, regions))
    )  # warm: jit traces, plan cache
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fold_once(engine, base, pool, weights, n_clients, regions)
        jax.block_until_ready(jax.tree.leaves(out))
        times.append(time.perf_counter() - t0)
    fold_s = statistics.median(times)

    us_per_client = fold_s / n_clients * 1e6
    entry = {
        "n_clients": n_clients,
        "regions": regions,
        "tree": "flat" if regions == 1 else f"{regions}-region",
        "n_params": N_PARAMS,
        "fold_s": round(fold_s, 6),
        "us_per_client": round(us_per_client, 3),
        "rounds_per_s": round(1.0 / fold_s, 3),
    }
    if flat8_us_per_client is not None:
        entry["vs_flat8_per_client"] = round(us_per_client / flat8_us_per_client, 3)
    print(
        f"[hierarchy] {entry['tree']} N={n_clients}: "
        f"fold={fold_s*1e3:.1f}ms {us_per_client:.1f}us/client "
        f"{entry['rounds_per_s']:.1f} rounds/s",
        file=sys.stderr,
    )
    return entry


def bench_engine_round(n_clients: int = ENGINE_COHORT, regions: int = 4) -> Dict[str, Any]:
    """Coordinator path (FoldEvents + bus summaries) vs a flat engine."""
    base = _base(N_PARAMS)
    pool = _update_pool(UPDATE_POOL, N_PARAMS)
    rng = np.random.default_rng(2)
    results = [
        ClientResult(f"c{i}", pool[i % len(pool)], int(rng.integers(1, 16)), 0.0)
        for i in range(n_clients)
    ]
    schedule = InstantSchedule()

    flat = AsyncRoundEngine(bus=NULL_BUS)
    coord = HierarchyCoordinator(
        partition_regions([r.client_id for r in results], regions), bus=NULL_BUS
    )

    def time_one(fold: Any) -> float:
        fold()  # warm
        times = []
        for _ in range(REPEATS):
            t0 = time.perf_counter()
            fold()
            times.append(time.perf_counter() - t0)
        return statistics.median(times)

    flat_s = time_one(
        lambda: flat.fold_round(0, results, schedule, base_params=base)
    )
    tree_s = time_one(
        lambda: coord.fold_round(0, results, schedule, base_params=base)
    )
    entry = {
        "n_clients": n_clients,
        "regions": regions,
        "flat_engine_s": round(flat_s, 6),
        "coordinator_s": round(tree_s, 6),
        "overhead_vs_flat": round(tree_s / flat_s, 3),
    }
    print(
        f"[hierarchy] engine N={n_clients}: flat={flat_s*1e3:.1f}ms "
        f"coordinator({regions} regions)={tree_s*1e3:.1f}ms "
        f"({entry['overhead_vs_flat']}x)",
        file=sys.stderr,
    )
    return entry


def run_grid(quick: bool = False, repeats: int = REPEATS) -> Dict[str, Any]:
    cohorts = QUICK_COHORTS if quick else FULL_COHORTS
    flat8 = bench_fold_tree(8, 1, repeats=repeats)  # paper-scale baseline
    entries = [flat8]
    for n in cohorts:
        flat_n: Dict[str, Any] = {}
        for r in TREES:
            e = bench_fold_tree(
                n, r, flat8_us_per_client=flat8["us_per_client"], repeats=repeats
            )
            if r == 1:
                flat_n = e
            else:
                e["overhead_vs_flat"] = round(e["fold_s"] / flat_n["fold_s"], 3)
            entries.append(e)
    return {
        "backend": jax.default_backend(),
        "grid": "quick" if quick else "full",
        "n_params": N_PARAMS,
        "entries": entries,
        "engine_round": bench_engine_round(),
    }


def bench_hierarchy() -> List[Row]:
    """run.py-compatible rows (quick grid)."""
    report = run_grid(quick=True, repeats=3)
    rows: List[Row] = []
    for e in report["entries"]:
        derived = (
            f"us_per_client={e['us_per_client']};"
            f"rounds_per_s={e['rounds_per_s']}"
        )
        if "vs_flat8_per_client" in e:
            derived += f";vs_flat8={e['vs_flat8_per_client']}"
        rows.append((f"hierarchy_{e['tree']}_{e['n_clients']}", e["fold_s"] * 1e6, derived))
    er = report["engine_round"]
    rows.append((
        f"hierarchy_engine_{er['regions']}region_{er['n_clients']}",
        er["coordinator_s"] * 1e6,
        f"flat_us={er['flat_engine_s']*1e6:.0f};overhead={er['overhead_vs_flat']}",
    ))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="small grid (CI smoke)")
    ap.add_argument("--repeats", type=int, default=REPEATS)
    ap.add_argument("--out", default="BENCH_hierarchy.json")
    args = ap.parse_args()

    report = run_grid(quick=args.quick, repeats=args.repeats)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"[hierarchy] wrote {args.out}", file=sys.stderr)

    print("name,us_per_call,derived")
    for e in report["entries"]:
        print(
            f"hierarchy_{e['tree']}_{e['n_clients']},"
            f"{e['fold_s']*1e6:.1f},"
            f"us_per_client={e['us_per_client']};"
            f"rounds_per_s={e['rounds_per_s']}"
        )


if __name__ == "__main__":
    main()
